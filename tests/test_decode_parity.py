"""Differential harness for the batched fused decode pipeline.

Three-way parity, seeded, across GQA group sizes, ragged per-row
depths and window set/unset:

    hata_decode_batched (one dispatch, per-row pos vector)
        ≡ looped hata_decode (B=1 slices, scalar pos)   [bit-exact]
        ≡ dense decode attention when cache_len <= k    [numerical]

plus the fused Pallas kernel (interpret mode) against the XLA
reference, including the bit-exactness of its *in-kernel* validity
masking, and property tests for the selection semantics the pipeline
rests on (top-k tie-breaking on integer hash scores, recall == 1.0
=> identical attention).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from hypothesis_compat import given, settings, st
from repro.configs.base import HataConfig
from repro.core import kvcache, topk
from repro.core.hash_attention import (clamped_budget, hata_decode,
                                       hata_decode_batched)
from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode_gathered_batched
from repro.kernels.hamming_score import hamming_score_batched

RNG = np.random.default_rng(7)
HCFG = HataConfig(rbit=64, budget_min=16, budget_max=32,
                  budget_frac=0.5)


def _setup(b, h_kv, g, d=32, s=64, seed=0):
    """Random filled cache with *consistent* key codes + a decode step."""
    rng = np.random.default_rng(seed)
    h = h_kv * g
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=HCFG.rbit,
                                  dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, HCFG.rbit)),
                    jnp.float32) / np.sqrt(d)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32))
    cache = dataclasses.replace(
        cache, codes=ops.hash_encode_heads(cache.k, w))
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    # ragged per-row depths, incl. one row at the cache edge
    pos = rng.integers(s // 4, s - 1, b)
    pos[-1] = s - 1
    return cache, w, q, k1, v1, jnp.asarray(pos, jnp.int32)


def _loop_rows(cache, w, q, k1, v1, pos, hcfg, window, fused):
    outs, idxs = [], []
    for i in range(q.shape[0]):
        ci = kvcache.LayerKVCache(k=cache.k[i:i + 1], v=cache.v[i:i + 1],
                                  codes=cache.codes[i:i + 1])
        ri = hata_decode(q[i:i + 1], k1[i:i + 1], v1[i:i + 1], w, ci,
                         hcfg=hcfg, pos=jnp.int32(int(pos[i])),
                         window=window, fused_gather=fused)
        outs.append(np.asarray(ri.out)[0])
        idxs.append(np.asarray(ri.idx)[0])
    return np.stack(outs), np.stack(idxs)


# ---------------------------------------------------------------------------
# batched == looped, bit-exact, both impls
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g", [1, 4, 8])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("impl,fused", [("xla", False), ("pallas", True)])
def test_batched_equals_looped(g, window, impl, fused):
    cache, w, q, k1, v1, pos = _setup(b=3, h_kv=2, g=g, seed=g)
    with ops.use_impl(impl):
        res = hata_decode_batched(q, k1, v1, w, cache, hcfg=HCFG,
                                  pos=pos, window=window,
                                  fused_gather=fused)
        out_l, idx_l = _loop_rows(cache, w, q, k1, v1, pos, HCFG,
                                  window, fused)
    assert_array_equal(np.asarray(res.idx), idx_l)
    assert_array_equal(np.asarray(res.out), out_l)


# ---------------------------------------------------------------------------
# batched == dense when the budget covers the cache
# ---------------------------------------------------------------------------
def _dense_ref(q, cache, n_valid, window):
    """Dense masked decode reference (per-row validity + SWA window)."""
    b, h, d = q.shape
    h_kv = cache.k.shape[2]
    s = cache.max_len
    pos = np.arange(s)
    nv = np.asarray(n_valid).reshape(-1, 1)
    valid = pos[None] < nv
    if window is not None:
        valid = valid & (pos[None] > nv - 1 - window)
    qf = np.asarray(q).reshape(b, h_kv, h // h_kv, d) * (d ** -0.5)
    logits = np.einsum("bhgd,bshd->bhgs", qf.astype(np.float64),
                       np.asarray(cache.k, np.float64))
    logits = np.where(valid[:, None, None], logits, -np.inf)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = np.einsum("bhgs,bshd->bhgd", p,
                    np.asarray(cache.v, np.float64))
    return out.reshape(b, h, d)


@pytest.mark.parametrize("g", [1, 4, 8])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("impl,fused", [("xla", False), ("pallas", True)])
def test_batched_equals_dense_when_budget_covers_cache(g, window, impl,
                                                       fused):
    cache, w, q, k1, v1, pos = _setup(b=3, h_kv=2, g=g, seed=10 + g)
    s = cache.max_len
    hcfg = dataclasses.replace(HCFG, budget_min=s, budget_max=s,
                               budget_frac=1.0)
    with ops.use_impl(impl):
        res = hata_decode_batched(q, k1, v1, w, cache, hcfg=hcfg,
                                  pos=pos, window=window,
                                  fused_gather=fused)
    want = _dense_ref(q, res.cache, np.asarray(pos) + 1, window)
    assert_allclose(np.asarray(res.out), want, atol=1e-5)


# ---------------------------------------------------------------------------
# fused kernel vs XLA reference — including in-kernel masking bit-exactness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g", [1, 4, 8])
def test_fused_kernel_matches_xla_reference(g):
    cache, w, q, k1, v1, pos = _setup(b=3, h_kv=2, g=g, seed=20 + g)
    with ops.use_impl("pallas"):
        rp = hata_decode_batched(q, k1, v1, w, cache, hcfg=HCFG,
                                 pos=pos, fused_gather=True)
    with ops.use_impl("xla"):
        rx = hata_decode_batched(q, k1, v1, w, cache, hcfg=HCFG,
                                 pos=pos, fused_gather=False)
    # identical integer scores -> identical selection
    assert_array_equal(np.asarray(rp.scores), np.asarray(rx.scores))
    assert_array_equal(np.asarray(rp.idx), np.asarray(rx.idx))
    assert_allclose(np.asarray(rp.out), np.asarray(rx.out), atol=1e-5)


@pytest.mark.parametrize("block_k", [8, 128])
def test_fused_kernel_masking_is_bit_exact(block_k):
    """Invalid selections must have exactly zero influence: repointing
    every invalid idx entry at different (arbitrary) cache rows cannot
    change a single output bit."""
    rng = np.random.default_rng(3)
    b, s, h_kv, g, d, k = 2, 48, 2, 4, 32, 24
    q = jnp.asarray(rng.standard_normal((b, h_kv, g, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, h_kv, d)), jnp.float32)
    idx = np.asarray(rng.integers(0, s, (b, h_kv, k)), np.int32)
    nv = rng.integers(1, k + 1, (b, h_kv))
    invalid = np.arange(k)[None, None, :] >= nv[..., None]
    idx2 = np.where(invalid, rng.integers(0, s, idx.shape), idx)
    assert (idx2 != idx).any()
    out = flash_decode_gathered_batched(q, kc, vc, jnp.asarray(idx),
                                        jnp.asarray(nv, jnp.int32),
                                        block_k=block_k, interpret=True)
    out2 = flash_decode_gathered_batched(q, kc, vc, jnp.asarray(idx2),
                                         jnp.asarray(nv, jnp.int32),
                                         block_k=block_k, interpret=True)
    assert_array_equal(np.asarray(out), np.asarray(out2))
    # and the masked fused output matches the -inf-masked XLA oracle
    sel_valid = jnp.arange(k)[None, None, :] < jnp.asarray(nv)[..., None]
    want = ref.masked_gather_decode_ref(
        q.reshape(b, h_kv * g, d), kc, vc, jnp.asarray(idx), sel_valid)
    assert_allclose(np.asarray(out).reshape(b, h_kv * g, d),
                    np.asarray(want), atol=1e-5)


def test_batched_hamming_kernel_matches_ref():
    rng = np.random.default_rng(4)
    b, s, h_kv, g, w_words, rbit = 2, 70, 3, 4, 2, 64
    qc = jnp.asarray(rng.integers(0, 2 ** 32, (b, h_kv, g, w_words),
                                  dtype=np.uint32))
    kc = jnp.asarray(rng.integers(0, 2 ** 32, (b, s, h_kv, w_words),
                                  dtype=np.uint32))
    got = hamming_score_batched(qc, kc, rbit=rbit, block_s=32,
                                interpret=True)
    want = ref.hamming_score_batched_ref(qc, kc, rbit)
    assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# selection-semantics properties (hypothesis; self-skip when absent)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(1, 24))
def test_topk_tie_breaking_matches_batched_kernel_scores(seed, g, k):
    """The batched kernel's integer scores are bit-identical to the
    oracle's, so lax.top_k (ties -> lowest index) picks the same rows
    on both paths — the invariant batched/looped parity rests on."""
    rng = np.random.default_rng(seed)
    b, s, h_kv, w_words, rbit = 2, 32, 2, 2, 64
    qc = jnp.asarray(rng.integers(0, 2 ** 32, (b, h_kv, g, w_words),
                                  dtype=np.uint32))
    kc = jnp.asarray(rng.integers(0, 2 ** 32, (b, s, h_kv, w_words),
                                  dtype=np.uint32))
    kernel = hamming_score_batched(qc, kc, rbit=rbit, interpret=True)
    oracle = ref.hamming_score_batched_ref(qc, kc, rbit)
    assert_array_equal(np.asarray(kernel), np.asarray(oracle))
    _, ik = topk.topk(kernel, min(k, s))
    _, io = topk.topk(oracle, min(k, s))
    assert_array_equal(np.asarray(ik), np.asarray(io))
    # tie-breaking contract: stable descending sort by (score, -index)
    sc = np.asarray(oracle)
    order = np.argsort(-sc, axis=-1, kind="stable")[..., :min(k, s)]
    assert_array_equal(np.asarray(io), order)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_recall_one_implies_identical_attention(seed):
    """selection_recall == 1.0 means the estimated top-k *set* equals
    the true top-k set, so attending over either selection (rows taken
    in cache order) is bit-identical."""
    rng = np.random.default_rng(seed)
    s, k, h, d = 32, 8, 2, 16
    true = rng.permutation(s).astype(np.float32)
    # same top-k set, different ordering inside and outside the set
    est = true.copy()
    top = np.argsort(-true, kind="stable")[:k]
    est[top] = true[top][::-1]
    rest = np.setdiff1d(np.arange(s), top)
    est[rest] = rng.permutation(est[rest])
    rec = topk.selection_recall(jnp.asarray(est)[None],
                                jnp.asarray(true)[None], k)
    assert float(rec[0]) == 1.0
    idx_t = np.sort(np.argsort(-true, kind="stable")[:k])
    idx_e = np.sort(np.argsort(-est, kind="stable")[:k])
    assert_array_equal(idx_t, idx_e)
    q = jnp.asarray(rng.standard_normal((1, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((1, s, 1, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((1, s, 1, d)), jnp.float32)
    out_t = ref.masked_gather_decode_ref(q, kc, vc,
                                         jnp.asarray(idx_t)[None, None])
    out_e = ref.masked_gather_decode_ref(q, kc, vc,
                                         jnp.asarray(idx_e)[None, None])
    assert_array_equal(np.asarray(out_t), np.asarray(out_e))
