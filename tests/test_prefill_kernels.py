"""Differential suite for the batched/paged flash-prefill kernel stack
and the sampled-serving RNG streams (PR 4).

Five layers of guarantees:

  1. Kernel parity — ``flash_prefill_batched`` equals the XLA
     online-softmax path bit-for-bit when the kv blockings coincide and
     the ``ref.py`` oracles to float tolerance, across GQA + MLA,
     ragged per-row ``q_offset``, window on/off.
  2. Chunk invariance — the traced-offset accumulation is invariant to
     the q-chunk partition: a prompt prefilled in chunks (boundaries
     straddling pages) equals the same prompt in one chunk bit-for-bit.
  3. Paged ≡ contiguous — the block-table kernels equal the contiguous
     kernels over the same logical view at the same (page-sized) kv
     blocking, bit-exact, GQA and MLA.
  4. Model parity — chunked paged prefill through the model stack
     reproduces the one-chunk prefill bit-exactly on both impls, and
     the engine's chunked prefill compiles exactly ONE chunk shape
     (traced ctx — no per-chunk-position recompile).
  5. Sampled serving — categorical outputs are bit-identical with and
     without forced preemption, and independent of co-scheduled
     traffic (per-request RNG streams); the binding-capacity MoE config
     warns/raises at engine construction.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from repro.configs import get_reduced
from repro.kernels import ops, ref
from repro.kernels.flash_attention import (flash_prefill_batched,
                                           flash_prefill_paged,
                                           mla_prefill_batched,
                                           mla_prefill_paged)
from repro.models import Model
from repro.serving import PagedServingEngine, Request, ServingEngine

RNG_SEED = 29


# ===========================================================================
# helpers
# ===========================================================================
def _gqa_inputs(b=2, sq=16, sk=48, h_kv=2, g=3, d=32, seed=0):
    rng = np.random.default_rng(seed)
    h = h_kv * g
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sk, h_kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sk, h_kv, d)), jnp.float32)
    off = jnp.asarray(rng.integers(0, max(sk - sq, 1), b), jnp.int32)
    return q, k, v, off


def _paged_from_contiguous(leaves, page, seed=0):
    """Scatter contiguous (B, S, ...) leaves into shuffled page pools.
    Returns (pools, block_table); page 0 stays scratch (all zeros)."""
    rng = np.random.default_rng(seed)
    b, s = leaves[0].shape[:2]
    t = s // page
    n_pages = b * t + 1
    perm = rng.permutation(n_pages - 1) + 1
    bt = perm.reshape(b, t).astype(np.int32)
    pools = []
    for leaf in leaves:
        pool = np.zeros((n_pages, page) + leaf.shape[2:],
                        np.asarray(leaf).dtype)
        for bi in range(b):
            for ti in range(t):
                pool[bt[bi, ti]] = np.asarray(
                    leaf[bi, ti * page:(ti + 1) * page])
        pools.append(jnp.asarray(pool))
    return pools, jnp.asarray(bt)


def _setup_model(arch, dropless=True):
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe and dropless:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)
            / cfg.moe.top_k))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen():
    return _setup_model("qwen1.5-0.5b")


@pytest.fixture(scope="module")
def deepseek():
    return _setup_model("deepseek-v2-lite-16b")


# ===========================================================================
# 1. kernel parity (vs the XLA path and the oracles)
# ===========================================================================
@pytest.mark.parametrize("window", [None, 8])
def test_prefill_batched_matches_xla_bit_exact(window):
    """Matched kv blocking (one tile == one chunk): the Pallas kernel
    and the XLA online-softmax path agree bit-for-bit, per-row ragged
    offsets included."""
    q, k, v, off = _gqa_inputs()
    sk = k.shape[1]
    got = flash_prefill_batched(q, k, v, off, causal=True,
                                window=window, block_q=8, block_k=sk)
    want = jnp.stack([
        ops._xla_flash_gqa(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                           causal=True, window=window,
                           q_offset=off[i])[0]
        for i in range(q.shape[0])])
    assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_prefill_batched_matches_oracle(causal, window):
    """Multi-tile online softmax vs the plain-softmax oracle."""
    q, k, v, _ = _gqa_inputs(sq=48, sk=48)
    got = flash_prefill_batched(q, k, v, None, causal=causal,
                                window=window, block_q=16, block_k=16)
    want = ref.mha_ref(q, k, v, causal=causal, window=window)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_ops_flash_attention_pallas_matches_xla():
    """The ops-level dispatch (the former vmap + jnp.repeat path) now
    routes through the batched kernel and stays on the oracle."""
    q, k, v, _ = _gqa_inputs(sq=32, sk=32)
    with ops.use_impl("xla"):
        want = ops.flash_attention(q, k, v, causal=True)
    with ops.use_impl("pallas"):
        got = ops.flash_attention(q, k, v, causal=True)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_mla_prefill_matches_oracle_bit_exact():
    rng = np.random.default_rng(RNG_SEED)
    b, c, h, r, rd, s = 2, 12, 4, 16, 8, 40
    q_lat = jnp.asarray(rng.standard_normal((b, c, h, r + rd)),
                        jnp.float32)
    ckv = jnp.asarray(rng.standard_normal((b, s, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((b, s, rd)), jnp.float32)
    off = jnp.asarray([5, 20], jnp.int32)
    got = mla_prefill_batched(q_lat, ckv, krope, off, lora_rank=r,
                              scale=0.125, block_q=4, block_k=s)
    want = ref.mla_chunk_attention_ref(q_lat, ckv, krope, off,
                                       lora_rank=r, scale=0.125)
    assert_array_equal(np.asarray(got), np.asarray(want))


# ===========================================================================
# 2. chunk invariance (traced q_offset — boundaries straddle pages)
# ===========================================================================
@pytest.mark.parametrize("chunk", [8, 12, 20])
def test_prefill_chunk_invariance_bit_exact(chunk):
    """Prefilling in chunks (widths that straddle the kv tiling) equals
    the one-chunk run bit-for-bit — the masked lanes carry exactly zero
    mass, so the accumulation can't see the q partition."""
    q, k, v, _ = _gqa_inputs(b=1, sq=48, sk=48)
    one = flash_prefill_batched(q, k, v, None, causal=True, block_q=8,
                                block_k=16)
    parts = []
    for ctx in range(0, 48, chunk):
        end = min(ctx + chunk, 48)
        parts.append(flash_prefill_batched(
            q[:, ctx:end], k, v, jnp.asarray([ctx], jnp.int32),
            causal=True, block_q=8, block_k=16))
    assert_array_equal(np.asarray(jnp.concatenate(parts, 1)),
                       np.asarray(one))


def test_mla_prefill_chunk_invariance_bit_exact():
    rng = np.random.default_rng(RNG_SEED + 1)
    b, s, h, r, rd = 1, 40, 4, 16, 8
    q_lat = jnp.asarray(rng.standard_normal((b, s, h, r + rd)),
                        jnp.float32)
    ckv = jnp.asarray(rng.standard_normal((b, s, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((b, s, rd)), jnp.float32)
    one = mla_prefill_batched(q_lat, ckv, krope, None, lora_rank=r,
                              scale=0.125, block_q=8, block_k=8)
    parts = []
    for ctx in range(0, s, 12):
        end = min(ctx + 12, s)
        parts.append(mla_prefill_batched(
            q_lat[:, ctx:end], ckv, krope,
            jnp.asarray([ctx], jnp.int32), lora_rank=r, scale=0.125,
            block_q=8, block_k=8))
    assert_array_equal(np.asarray(jnp.concatenate(parts, 1)),
                       np.asarray(one))


# ===========================================================================
# 3. paged ≡ contiguous (same logical view, page-sized kv blocking)
# ===========================================================================
@pytest.mark.parametrize("window", [None, 8])
def test_prefill_paged_equals_contiguous_bit_exact(window):
    q, k, v, off = _gqa_inputs(sq=16, sk=48)
    (k_pool, v_pool), bt = _paged_from_contiguous([k, v], page=8,
                                                  seed=3)
    got = flash_prefill_paged(q, k_pool, v_pool, bt, off,
                              window=window, block_q=8)
    want = flash_prefill_batched(q, k, v, off, causal=True,
                                 window=window, block_q=8, block_k=8)
    assert_array_equal(np.asarray(got), np.asarray(want))


def test_mla_prefill_paged_equals_contiguous_bit_exact():
    rng = np.random.default_rng(RNG_SEED + 2)
    b, c, h, r, rd, s = 2, 12, 4, 16, 8, 48
    q_lat = jnp.asarray(rng.standard_normal((b, c, h, r + rd)),
                        jnp.float32)
    ckv = jnp.asarray(rng.standard_normal((b, s, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((b, s, rd)), jnp.float32)
    off = jnp.asarray([7, 30], jnp.int32)
    (c_pool, r_pool), bt = _paged_from_contiguous([ckv, krope], page=8,
                                                  seed=4)
    got = mla_prefill_paged(q_lat, c_pool, r_pool, bt, off, lora_rank=r,
                            scale=0.125, block_q=4)
    want = mla_prefill_batched(q_lat, ckv, krope, off, lora_rank=r,
                               scale=0.125, block_q=4, block_k=8)
    assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_chunk_attention_paged_ops_parity(impl):
    """The ops entry point agrees with the contiguous chunk attention
    over the gathered logical view on both impls (the xla impl *is* the
    gathered reference; the pallas impl reads pages in place)."""
    q, k, v, off = _gqa_inputs(sq=16, sk=48)
    (k_pool, v_pool), bt = _paged_from_contiguous([k, v], page=8,
                                                  seed=5)
    with ops.use_impl(impl):
        got = ops.chunk_attention_paged(q, k_pool, v_pool, bt, off)
        want = ops.chunk_attention(q, k, v, q_offset=off)
    assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ===========================================================================
# 4. model parity + one compiled chunk shape
# ===========================================================================
def _run_chunks(model, params, prompt, pools, bt, chunk):
    from repro.core import cache_view
    logits = None
    views = [cache_view.paged_view(p_, bt) for p_ in pools]
    for ctx in range(0, len(prompt), chunk):
        end = min(ctx + chunk, len(prompt))
        toks = np.zeros(chunk, np.int32)
        toks[:end - ctx] = prompt[ctx:end]
        logits, views = model.prefill_chunk(
            params, jnp.asarray(toks[None]), views,
            jnp.int32(ctx), jnp.int32(end - ctx - 1))
    return logits, [v.unwrap() for v in views]


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "deepseek-v2-lite-16b"])
def test_chunked_equals_one_chunk_prefill_bit_exact(arch, impl,
                                                    request):
    """Chunked paged prefill ≡ the whole prompt in ONE chunk through
    the same kernel stack, bit-exact, GQA and MLA (+MoE at dropless
    capacity), on the XLA path and the Pallas kernels alike."""
    cfg, model, params = request.getfixturevalue(
        "qwen" if arch.startswith("qwen") else "deepseek")
    rng = np.random.default_rng(RNG_SEED + 3)
    prompt = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    page, t = 8, 4
    bt = jnp.arange(1, t + 1, dtype=jnp.int32)[None]
    with ops.use_impl(impl):
        chunked, _ = _run_chunks(model, params, prompt,
                                 model.init_paged_pools(t + 1, page),
                                 bt, chunk=8)
        one, _ = _run_chunks(model, params, prompt,
                             model.init_paged_pools(t + 1, page),
                             bt, chunk=len(prompt))
    assert_array_equal(np.asarray(chunked), np.asarray(one))


def test_mla_chunked_close_to_monolithic(deepseek):
    """The absorbed-q latent prefill reproduces the materialized-K/V
    monolithic prefill to float tolerance (the math is identical;
    only the contraction order differs)."""
    cfg, model, params = deepseek
    rng = np.random.default_rng(RNG_SEED + 4)
    prompt = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    caches = model.init_caches(1, 32, layout="list")
    want, _ = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            caches, jnp.int32(0))
    page, t = 8, 4
    bt = jnp.arange(1, t + 1, dtype=jnp.int32)[None]
    got, _ = _run_chunks(model, params, prompt,
                         model.init_paged_pools(t + 1, page), bt,
                         chunk=8)
    assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                    rtol=1e-4)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_engine_compiles_one_chunk_shape(qwen, impl):
    """The engine's jitted chunk step serves every chunk position and
    prompt length from ONE compiled shape (traced ctx/last) — on the
    pallas impl that one shape runs the block-table flash-prefill
    kernel over the pool in place."""
    cfg, model, params = qwen
    rng = np.random.default_rng(RNG_SEED + 5)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        n).astype(np.int32),
                    max_new_tokens=3) for n in (6, 13, 22)]
    with ops.use_impl(impl):
        eng = PagedServingEngine(model, params, num_pages=16,
                                 page_size=8, max_batch=2,
                                 prefill_chunk=8)
        done = eng.run(reqs)
    assert eng.stats["prefill_chunks"] >= 6      # many chunk positions
    assert eng._chunk._cache_size() == 1         # ... ONE compiled shape
    assert eng._decode._cache_size() == 1
    for r in done:
        assert len(r.output) == 3 and not r.truncated


def test_engine_pallas_matches_xla_outputs(qwen):
    """The paged engine emits identical greedy tokens whether chunks
    run the Pallas paged kernel or the XLA reference."""
    cfg, model, params = qwen
    rng = np.random.default_rng(RNG_SEED + 6)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 18)]
    outs = {}
    for impl in ("xla", "pallas"):
        with ops.use_impl(impl):
            eng = PagedServingEngine(model, params, num_pages=16,
                                     page_size=8, max_batch=2,
                                     prefill_chunk=8)
            done = eng.run([Request(prompt=p.copy(), max_new_tokens=4,
                                    id=1000 + i)
                            for i, p in enumerate(prompts)])
        outs[impl] = {r.id: r.output for r in done}
    assert outs["xla"] == outs["pallas"]


# ===========================================================================
# 5. sampled serving: RNG streams, preemption replay, MoE capacity
# ===========================================================================
def test_sampled_preemption_replay_bit_exact(qwen):
    """Categorical sampling survives a forced preemption bit-exactly:
    the replayed request re-derives the same (id, step) keys, so the
    tight-pool engine (preempting) and the roomy-pool engine emit
    identical tokens."""
    cfg, model, params = qwen
    rng = np.random.default_rng(RNG_SEED + 7)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]

    def run(num_pages):
        reqs = [Request(prompt=p.copy(), max_new_tokens=16,
                        id=2000 + i) for i, p in enumerate(prompts)]
        eng = PagedServingEngine(model, params, num_pages=num_pages,
                                 page_size=8, max_batch=3,
                                 max_len_pages=8, prefill_chunk=8,
                                 prefix_sharing=False,
                                 sample="categorical", seed=7)
        done = eng.run(reqs)
        return eng, {r.id: r.output for r in done}

    tight_eng, tight = run(num_pages=9)
    roomy_eng, roomy = run(num_pages=64)
    assert tight_eng.stats["preemptions"] >= 1
    assert roomy_eng.stats["preemptions"] == 0
    assert tight == roomy


def test_sampled_rng_isolated_from_cotenants(qwen):
    """Same request, same seed, different co-scheduled traffic → same
    sampled tokens (randomness is never consumed for other slots or
    empty waves)."""
    cfg, model, params = qwen
    rng = np.random.default_rng(RNG_SEED + 8)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    others = [rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
              for _ in range(3)]

    def run(cotenants):
        reqs = [Request(prompt=prompt.copy(), max_new_tokens=8,
                        id=3000)]
        reqs += [Request(prompt=p.copy(), max_new_tokens=8,
                         id=3001 + i)
                 for i, p in enumerate(cotenants)]
        eng = PagedServingEngine(model, params, num_pages=32,
                                 page_size=8, max_batch=2,
                                 max_len_pages=4, prefill_chunk=8,
                                 sample="categorical", seed=11)
        done = eng.run(reqs)
        return next(r.output for r in done if r.id == 3000)

    assert run([]) == run(others)


def test_dense_engine_sampled_rng_isolated(qwen):
    """The dense slot engine gets the same per-request streams."""
    cfg, model, params = qwen
    rng = np.random.default_rng(RNG_SEED + 9)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    other = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)

    def run(cotenant):
        reqs = [Request(prompt=prompt.copy(), max_new_tokens=8,
                        id=4000)]
        if cotenant:
            reqs.append(Request(prompt=other.copy(), max_new_tokens=8,
                                id=4001))
        eng = ServingEngine(model, params, max_batch=2, max_len=32,
                            sample="categorical", seed=13)
        done = eng.run(reqs)
        return next(r.output for r in done if r.id == 4000)

    assert run(False) == run(True)


def test_moe_binding_capacity_warns_and_raises():
    cfg, model, params = _setup_model("deepseek-v2-lite-16b",
                                      dropless=False)
    e = cfg.moe
    assert e.capacity_factor * e.top_k < e.n_experts  # binding config
    with pytest.warns(UserWarning, match="capacity_factor"):
        PagedServingEngine(model, params, num_pages=8, page_size=8)
    with pytest.raises(ValueError, match="capacity_factor"):
        PagedServingEngine(model, params, num_pages=8, page_size=8,
                           strict_moe_capacity=True)


def test_moe_dropless_capacity_is_silent(deepseek):
    cfg, model, params = deepseek
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        PagedServingEngine(model, params, num_pages=8, page_size=8)
