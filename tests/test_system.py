"""End-to-end behaviour: train a tiny LM, hash-train on its real q/k,
and verify the paper's claims in miniature — selection recall beats
random LSH, rbit/budget ablation trends (Fig. 7/8), HATA decode tracks
dense decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import HataConfig
from repro.core import hashing
from repro.data.hash_dataset import build_triplets_per_head, harvest_qk
from repro.data.synthetic import SyntheticLM
from repro.launch.train import main as train_main
from repro.models import Model


@pytest.fixture(scope="module")
def trained_tiny_lm():
    """Train a tiny llama-family LM on the induction task so its
    attention heads develop real retrieval structure."""
    cfg = get_reduced("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import adamw_init
    step = jax.jit(make_train_step(model, base_lr=1e-3,
                                   total_steps=150),
                   donate_argnums=(0, 1))
    opt = adamw_init(params)
    src = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    losses = []
    for i in range(150):
        batch = {"tokens": jnp.asarray(src.batch_at(i))}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    return cfg, model, params, losses


def test_training_reduces_loss(trained_tiny_lm):
    # margin asserts direction with headroom, not a convergence level:
    # the 150-step fixture lands at ~0.48 improvement on jax 0.4.x CPU
    # (0.5+ on newer jax), so 0.5 sat exactly on the noise floor.
    _, _, _, losses = trained_tiny_lm
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.35


def test_hash_recall_beats_lsh_on_real_qk(trained_tiny_lm):
    """Paper Fig. 1/8 in miniature: trained hashing beats random
    projections at equal bits on a real model's q/k geometry."""
    cfg, model, params, _ = trained_tiny_lm
    hcfg = dataclasses.replace(cfg.hata, rbit=64)
    src = SyntheticLM(cfg.vocab_size, 96, 1, seed=7)
    batches = [{"tokens": jnp.asarray(src.batch_at(i))}
               for i in range(3)]
    layer = cfg.n_layers - 1
    q, k, s = build_triplets_per_head(model, params, batches[:2], layer,
                                      hcfg, n_queries=48, m_keys=48)
    w = hashing.train_hash_weights_per_head(
        jax.random.PRNGKey(0), jnp.asarray(q), jnp.asarray(k),
        jnp.asarray(s), rbit=64, hcfg=hcfg)
    qh, kh = harvest_qk(model, params, batches[2], layer)
    h_kv = kh.shape[2]
    g = qh.shape[2] // h_kv
    budget = 10
    recs, recs_lsh = [], []
    w_lsh = hashing.random_projection_lsh(jax.random.PRNGKey(9),
                                          qh.shape[-1], 64)
    for hi in range(h_kv):
        qs = jnp.asarray(qh[0, 48:, hi * g])
        ks = jnp.asarray(kh[0, :, hi])
        recs.append(float(hashing.hash_topk_recall(
            qs, ks, w[hi], budget, rbit=64).mean()))
        recs_lsh.append(float(hashing.hash_topk_recall(
            qs, ks, w_lsh, budget, rbit=64).mean()))
    assert np.mean(recs) > np.mean(recs_lsh), (recs, recs_lsh)


def test_rbit_monotone_trend(trained_tiny_lm):
    """Fig. 8: recall improves with hash bits (32 -> 128)."""
    cfg, model, params, _ = trained_tiny_lm
    src = SyntheticLM(cfg.vocab_size, 96, 1, seed=11)
    batches = [{"tokens": jnp.asarray(src.batch_at(i))}
               for i in range(2)]
    layer = cfg.n_layers - 1
    recalls = {}
    for rbit in (32, 128):
        hcfg = dataclasses.replace(cfg.hata, rbit=rbit)
        q, k, s = build_triplets_per_head(
            model, params, batches[:1], layer, hcfg, n_queries=48,
            m_keys=48)
        w = hashing.train_hash_weights_per_head(
            jax.random.PRNGKey(0), jnp.asarray(q), jnp.asarray(k),
            jnp.asarray(s), rbit=rbit, hcfg=hcfg)
        qh, kh = harvest_qk(model, params, batches[1], layer)
        qs = jnp.asarray(qh[0, 48:, 0])
        ks = jnp.asarray(kh[0, :, 0])
        recalls[rbit] = float(hashing.hash_topk_recall(
            qs, ks, w[0], 10, rbit=rbit).mean())
    assert recalls[128] >= recalls[32] - 0.05, recalls


def test_hata_decode_tracks_dense_at_moderate_budget(trained_tiny_lm):
    """Next-token agreement between HATA decode and dense decode on the
    trained model at a 25% token budget."""
    cfg, model, params, _ = trained_tiny_lm
    src = SyntheticLM(cfg.vocab_size, 48, 4, seed=13)
    toks = jnp.asarray(src.batch_at(0))
    dense_tok = hata_tok = None
    for enabled in (False, True):
        cfg2 = dataclasses.replace(
            cfg, hata=dataclasses.replace(
                cfg.hata, enabled=enabled, budget_frac=0.25,
                budget_min=16, budget_max=64, rbit=64))
        m2 = Model(cfg2)
        caches = m2.init_caches(4, 64)
        logits, caches = m2.prefill(
            params, {"tokens": toks}, caches, jnp.int32(0))
        nxt, _ = m2.decode_step(params,
                                jnp.argmax(logits, -1).astype(jnp.int32),
                                caches, jnp.int32(48))
        if not enabled:
            dense_tok = np.asarray(jnp.argmax(nxt, -1))
        else:
            hata_tok = np.asarray(jnp.argmax(nxt, -1))
    # untrained random hash weights + 25% budget: most tokens agree
    assert (dense_tok == hata_tok).mean() >= 0.5


def test_train_driver_end_to_end(tmp_path):
    losses = train_main(["--arch", "llama3.1-8b", "--reduced",
                         "--steps", "60", "--batch", "4", "--seq", "48",
                         "--lr", "2e-3", "--log-every", "100",
                         "--ckpt-dir", str(tmp_path)])
    assert len(losses) == 60
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
