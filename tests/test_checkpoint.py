"""Checkpointing: atomicity, roundtrip, elastic restore, deterministic
data resume (fault-tolerance contract)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.checkpoint import Checkpointer
from repro.data.synthetic import SyntheticLM


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros(8)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = _state()
    ck.save(5, state, blocking=True)
    assert ck.latest() == 5
    got = ck.restore(5, jax.tree.map(jnp.zeros_like, state))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state())
    ck.wait()
    assert ck.latest() == 1


def test_interrupted_save_is_invisible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _state(), blocking=True)
    # simulate a crash mid-save: a tmp dir with partial contents
    crash = tmp_path / "step_0000000009.tmp-dead"
    crash.mkdir()
    (crash / "arr_00000.npy").write_bytes(b"partial")
    assert ck.latest() == 5          # tmp dirs never count
    # ... and a dir without a manifest doesn't either
    bad = tmp_path / "step_0000000010"
    bad.mkdir()
    assert ck.latest() == 5


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_fingerprint_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path), fingerprint="aaaa")
    ck.save(1, _state(), blocking=True)
    ck2 = Checkpointer(str(tmp_path), fingerprint="bbbb")
    with pytest.raises(ValueError):
        ck2.restore(1, _state())


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _state(), blocking=True)
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros(8)},
           "step": jnp.int32(0)}
    with pytest.raises(ValueError):
        ck.restore(1, bad)


def test_elastic_restore_new_mesh(tmp_path):
    """Save unsharded, restore onto a different device topology."""
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _state(), blocking=True)
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer

from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
ck = Checkpointer({str(tmp_path)!r})
like = {{"params": {{"w": jnp.zeros((8, 8)), "b": jnp.zeros(8)}},
        "step": jnp.int32(0)}}
sh = {{"params": {{"w": NamedSharding(mesh, P("data", "model")),
                 "b": NamedSharding(mesh, P("model"))}},
      "step": NamedSharding(mesh, P())}}
got = ck.restore(3, like, shardings=sh)
assert got["params"]["w"].sharding.spec == P("data", "model")
assert int(got["step"]) == 7
print("ELASTIC-OK")
"""
    out = run_subprocess(code, n_devices=4)
    assert "ELASTIC-OK" in out


def test_data_pipeline_deterministic_resume():
    src = SyntheticLM(128, 16, 4, seed=3)
    a = src.batch_at(17)
    b = src.batch_at(17)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(src.batch_at(17), src.batch_at(18))


def test_train_restart_identical_loss(tmp_path):
    """Kill a run at step 6, resume from ckpt; losses match an
    uninterrupted run exactly (deterministic data skip + state)."""
    from repro.launch.train import main as train_main
    args = ["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "12",
            "--batch", "2", "--seq", "32", "--log-every", "100"]
    full = train_main(args + ["--ckpt-dir", str(tmp_path / "a"),
                              "--ckpt-every", "6"])
    part1 = train_main(args[:4] + ["6"] + args[5:]
                       + ["--ckpt-dir", str(tmp_path / "b"),
                          "--ckpt-every", "6"])
    part2 = train_main(args + ["--ckpt-dir", str(tmp_path / "b"),
                               "--ckpt-every", "6"])
    np.testing.assert_allclose(full[6:], part2[:6], rtol=1e-5)
