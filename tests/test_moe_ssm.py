"""MoE dispatch and Mamba2 SSD internals vs naive references."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.configs import get_reduced
from repro.configs.base import SSMConfig
from repro.models import moe as moe_mod
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(0)


def _naive_moe(cfg, p, x):
    """Every token through its top-k experts, no capacity limits."""
    e = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    weights, experts, _ = moe_mod._router(e, logits)
    out = np.zeros_like(np.asarray(xf), dtype=np.float32)
    for t in range(xf.shape[0]):
        for j in range(e.top_k):
            ei = int(experts[t, j])
            h = (jax.nn.silu(xf[t] @ p["wi"][ei])
                 * (xf[t] @ p["wu"][ei])) @ p["wd"][ei]
            out[t] += float(weights[t, j]) * 0 + np.asarray(
                h, np.float32) * float(weights[t, j])
    out = jnp.asarray(out.reshape(b, s, d))
    if e.n_shared_experts:
        from repro.models.layers import ffn
        out = out + ffn(p["shared"], x)
    return out


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "deepseek-v2-lite-16b"])
def test_moe_dropless_matches_naive(arch):
    cfg = get_reduced(arch, d_model=32)
    cfg = dataclasses.replace(cfg, dtype="float32")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.n_experts)
        / cfg.moe.top_k))
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(cfg, key)
    x = jnp.asarray(RNG.standard_normal((2, 8, 32)), jnp.float32)
    got, aux = moe_mod.moe_ffn(cfg, p, x, group_size=16)
    want = _naive_moe(cfg, p, x)
    assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_when_tight():
    cfg = get_reduced("mixtral-8x22b", d_model=32)
    cfg = dataclasses.replace(cfg, dtype="float32")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.25))      # deliberately tiny
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.standard_normal((2, 16, 32)), jnp.float32)
    got, _ = moe_mod.moe_ffn(cfg, p, x, group_size=32)
    want = _naive_moe(cfg, p, x)
    # some tokens dropped -> outputs differ
    assert float(jnp.abs(got - want).max()) > 1e-3


def test_ssd_chunked_matches_naive_recurrence():
    B, S, nh, hd, N, chunk = 2, 48, 3, 8, 16, 16
    x = jnp.asarray(RNG.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((B, S, nh, N)), jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((B, S, nh, N)), jnp.float32)
    s0 = jnp.zeros((B, nh, hd, N))
    y, sf = ssd_chunked(x, dt, a, bm, cm, s0, chunk)
    s = np.zeros((B, nh, hd, N))
    ys = []
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * np.asarray(a)[None])
        s = s * decay[..., None, None] + np.einsum(
            "bhd,bhn,bh->bhdn", np.asarray(x[:, t]),
            np.asarray(bm[:, t]), np.asarray(dt[:, t]))
        ys.append(np.einsum("bhdn,bhn->bhd", s, np.asarray(cm[:, t])))
    assert_allclose(np.asarray(y), np.stack(ys, 1), atol=1e-4)
    assert_allclose(np.asarray(sf), s, atol=1e-4)


def test_ssd_nondivisible_length_padding():
    B, S, nh, hd, N = 1, 37, 2, 8, 8           # 37 % 16 != 0
    x = jnp.asarray(RNG.standard_normal((B, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, nh)), jnp.float32)
    a = -jnp.ones((nh,))
    bm = jnp.asarray(RNG.standard_normal((B, S, nh, N)), jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((B, S, nh, N)), jnp.float32)
    s0 = jnp.zeros((B, nh, hd, N))
    y16, _ = ssd_chunked(x, dt, a, bm, cm, s0, 16)
    y37, _ = ssd_chunked(x, dt, a, bm, cm, s0, 37)   # single chunk
    assert y16.shape == (B, S, nh, hd)
    assert_allclose(np.asarray(y16), np.asarray(y37), atol=1e-4)


def test_ssm_decode_matches_forward():
    """Per-token recurrent decode == chunked forward on the same seq."""
    from repro.models.ssm import ssm_decode, ssm_forward, ssm_init
    cfg = get_reduced("mamba2-130m")
    cfg = dataclasses.replace(cfg, dtype="float32")
    from repro.models import Model
    p = ssm_init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    x = jnp.asarray(RNG.standard_normal((B, S, cfg.d_model)),
                    jnp.float32) * 0.5
    y_full, state = ssm_forward(cfg, p, x, return_state=True)
    # replay token by token
    from repro.core.kvcache import SSMState
    from repro.models.ssm import ssm_dims
    di, nh, conv_dim = ssm_dims(cfg)
    st = SSMState(conv=jnp.zeros((B, cfg.ssm.d_conv - 1, conv_dim)),
                  ssm=jnp.zeros((B, nh, cfg.ssm.head_dim,
                                 cfg.ssm.d_state)))
    outs = []
    for t in range(S):
        y, st = ssm_decode(cfg, p, x[:, t:t + 1], st)
        outs.append(y[:, 0])
    y_step = jnp.stack(outs, 1)
    assert_allclose(np.asarray(y_step), np.asarray(y_full), atol=2e-4)
    assert_allclose(np.asarray(st.ssm), np.asarray(state.ssm),
                    atol=2e-4)
