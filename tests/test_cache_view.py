"""View-contract suite for ``core/cache_view.py``.

The one-CacheView-API redesign (PR 5) is held to a differential
contract:

  1. **Layout transparency** — ``gqa_decode_attend`` / ``mla_decode_attend``
     produce bit-identical outputs whether addressed through a raw
     cache, a :class:`ContiguousView`, a :class:`PagedView`, or a
     tiered :class:`OffloadedView` (host K/V, resident codes) holding
     the same rows (GQA + MLA, ragged depths, window on/off, xla and
     pallas-interpret impls); the offloaded PCIe byte ledger is exact
     per wave.
  2. **Chunked prefill transparency** — ``Model.prefill_chunk`` over
     ``ContiguousView``s equals the same chunks over ``PagedView``s
     equals the monolithic prefill.
  3. **Shim fidelity** — the deprecated ``decode_step_paged`` /
     ``prefill_chunk_paged`` wrappers warn and return exactly what the
     view API returns.
  4. **Windowed paged prefill page-skip** — the rebased, grid-cut
     sliding-window walk of ``flash_prefill_paged`` is bit-exact vs the
     full-table walk (the contiguous kernel at page blocking).
  5. **Sequence-parallel sweep (slow)** — ``ShardedView``-over-pages ≡
     contiguous SP ≡ single-device decode for two_stage (exact) and
     paged ≡ contiguous for local_split, GQA and MLA, on 8 host
     devices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from repro.configs import get_reduced
from repro.core import cache_view as cv
from repro.core import kvcache
from repro.core.paged_cache import PagedKVPool, PagedMLAPool
from repro.kernels import ops
from repro.models import Model
from repro.models import attention as attn

PAGE = 8


def _gqa_cfg(window=None, budget=16):
    cfg = get_reduced("qwen1.5-0.5b")
    return dataclasses.replace(
        cfg, dtype="float32", sliding_window=window,
        hata=dataclasses.replace(cfg.hata, budget_min=budget,
                                 budget_max=budget))


def _mla_cfg(budget=16):
    cfg = get_reduced("deepseek-v2-lite-16b")
    return dataclasses.replace(
        cfg, dtype="float32",
        hata=dataclasses.replace(cfg.hata, budget_min=budget,
                                 budget_max=budget))


def _gqa_pair(cfg, b=2, t=6, seed=0):
    """A contiguous cache and a paged pool holding the same rows
    (shuffled page assignment, page 0 = scratch), plus ragged depths."""
    rng = np.random.default_rng(seed)
    h_kv, d, rbit = cfg.n_kv_heads, cfg.head_dim, cfg.hata.rbit
    s = t * PAGE
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=rbit,
                                  dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2 ** 32, cache.codes.shape,
                                       dtype=np.uint32)))
    n_pages = b * t + 1
    perm = rng.permutation(n_pages - 1) + 1
    bt = perm.reshape(b, t).astype(np.int32)
    k_pool = np.zeros((n_pages, PAGE, h_kv, d), np.float32)
    v_pool = np.zeros((n_pages, PAGE, h_kv, d), np.float32)
    c_pool = np.zeros((n_pages, PAGE, h_kv, rbit // 32), np.uint32)
    for bi in range(b):
        for ti in range(t):
            rows = slice(ti * PAGE, (ti + 1) * PAGE)
            k_pool[bt[bi, ti]] = np.asarray(cache.k[bi, rows])
            v_pool[bt[bi, ti]] = np.asarray(cache.v[bi, rows])
            c_pool[bt[bi, ti]] = np.asarray(cache.codes[bi, rows])
    pool = PagedKVPool(k=jnp.asarray(k_pool), v=jnp.asarray(v_pool),
                       codes=jnp.asarray(c_pool))
    pos = jnp.asarray(rng.integers(PAGE, s - 2, b), jnp.int32)
    return cache, pool, jnp.asarray(bt), pos


def _mla_pair(cfg, b=2, t=6, seed=1):
    rng = np.random.default_rng(seed)
    m = cfg.mla
    r, rd, rbit = m.kv_lora_rank, m.qk_rope_dim, cfg.hata.rbit
    s = t * PAGE
    cache = kvcache.init_mla_cache(b, s, r, rd, rbit=rbit,
                                   dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        ckv=jnp.asarray(rng.standard_normal(cache.ckv.shape),
                        jnp.float32),
        krope=jnp.asarray(rng.standard_normal(cache.krope.shape),
                          jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2 ** 32, cache.codes.shape,
                                       dtype=np.uint32)))
    n_pages = b * t + 1
    perm = rng.permutation(n_pages - 1) + 1
    bt = perm.reshape(b, t).astype(np.int32)
    c_pool = np.zeros((n_pages, PAGE, r), np.float32)
    r_pool = np.zeros((n_pages, PAGE, rd), np.float32)
    h_pool = np.zeros((n_pages, PAGE, rbit // 32), np.uint32)
    for bi in range(b):
        for ti in range(t):
            rows = slice(ti * PAGE, (ti + 1) * PAGE)
            c_pool[bt[bi, ti]] = np.asarray(cache.ckv[bi, rows])
            r_pool[bt[bi, ti]] = np.asarray(cache.krope[bi, rows])
            h_pool[bt[bi, ti]] = np.asarray(cache.codes[bi, rows])
    pool = PagedMLAPool(ckv=jnp.asarray(c_pool),
                        krope=jnp.asarray(r_pool),
                        codes=jnp.asarray(h_pool))
    pos = jnp.asarray(rng.integers(PAGE, s - 2, b), jnp.int32)
    return cache, pool, jnp.asarray(bt), pos


def _offload_twin_gqa(pool):
    """An ``OffloadedKVPool`` holding the same rows as a PagedKVPool:
    hash codes stay device-resident verbatim; K/V rows move to host."""
    from repro.core import offload
    opool = offload.init_offloaded_kv_pool(
        pool.num_pages, pool.page_size, pool.k.shape[2],
        pool.k.shape[3], rbit=pool.codes.shape[-1] * 32)
    opool = dataclasses.replace(opool, codes=pool.codes)
    opool.host.k[...] = np.asarray(pool.k)
    opool.host.v[...] = np.asarray(pool.v)
    return opool


def _offload_twin_mla(pool):
    from repro.core import offload
    opool = offload.init_offloaded_mla_pool(
        pool.num_pages, pool.page_size, pool.ckv.shape[2],
        pool.krope.shape[2], rbit=pool.codes.shape[-1] * 32)
    opool = dataclasses.replace(opool, codes=pool.codes)
    opool.host.ckv[...] = np.asarray(pool.ckv)
    opool.host.krope[...] = np.asarray(pool.krope)
    return opool


# ===========================================================================
# 1. layout transparency at the attend entry points
# ===========================================================================
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("use_hata", [True, False])
def test_gqa_decode_attend_views_bit_exact(impl, window, use_hata):
    cfg = _gqa_cfg(window=window)
    cache, pool, bt, pos = _gqa_pair(cfg, seed=2)
    rng = np.random.default_rng(2)
    p = attn.gqa_init(cfg, jax.random.PRNGKey(0))
    w_h = attn.gqa_hash_init(cfg, jax.random.PRNGKey(1))
    q1 = jnp.asarray(rng.standard_normal(
        (2, cfg.n_heads, cfg.head_dim)), jnp.float32)
    with ops.use_impl(impl):
        raw = attn.gqa_decode_attend(cfg, p, w_h, q1, cache, pos,
                                     use_hata)
        contig = attn.gqa_decode_attend(
            cfg, p, w_h, q1, cv.ContiguousView(cache), pos, use_hata)
        paged_ = attn.gqa_decode_attend(
            cfg, p, w_h, q1, cv.PagedView(pool, bt), pos, use_hata)
        off = attn.gqa_decode_attend(
            cfg, p, w_h, q1,
            cv.OffloadedView(_offload_twin_gqa(pool), bt), pos,
            use_hata)
    assert_array_equal(np.asarray(raw), np.asarray(contig))
    assert_array_equal(np.asarray(contig), np.asarray(paged_))
    # the tiered pool scores over the same resident codes and attends
    # over host-gathered rows through the same fused kernel: bit-exact
    # (use_hata=False exercises the dense kv_logical upload path)
    assert_array_equal(np.asarray(contig), np.asarray(off))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("use_hata", [True, False])
def test_mla_decode_attend_views_bit_exact(impl, use_hata):
    cfg = _mla_cfg()
    cache, pool, bt, pos = _mla_pair(cfg, seed=3)
    rng = np.random.default_rng(3)
    m = cfg.mla
    p = attn.mla_init(cfg, jax.random.PRNGKey(0))
    w_h = attn.mla_hash_init(cfg, jax.random.PRNGKey(1))
    q_lat = jnp.asarray(rng.standard_normal(
        (2, cfg.n_heads, m.kv_lora_rank + m.qk_rope_dim)), jnp.float32)
    with ops.use_impl(impl):
        raw = attn.mla_decode_attend(cfg, p, w_h, q_lat, cache, pos,
                                     use_hata, jnp.float32)
        contig = attn.mla_decode_attend(
            cfg, p, w_h, q_lat, cv.ContiguousMLAView(cache), pos,
            use_hata, jnp.float32)
        paged_ = attn.mla_decode_attend(
            cfg, p, w_h, q_lat, cv.PagedMLAView(pool, bt), pos,
            use_hata, jnp.float32)
        off = attn.mla_decode_attend(
            cfg, p, w_h, q_lat,
            cv.OffloadedMLAView(_offload_twin_mla(pool), bt), pos,
            use_hata, jnp.float32)
    assert_array_equal(np.asarray(raw), np.asarray(contig))
    assert_array_equal(np.asarray(contig), np.asarray(paged_))
    assert_array_equal(np.asarray(contig), np.asarray(off))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_gqa_decode_append_and_traced_flag(impl):
    """Full decode step (append + attend) through both view layouts,
    with the *traced* use_hata flag (the scanned-stack form)."""
    cfg = _gqa_cfg()
    cache, pool, bt, pos = _gqa_pair(cfg, seed=4)
    rng = np.random.default_rng(4)
    p = attn.gqa_init(cfg, jax.random.PRNGKey(0))
    w_h = attn.gqa_hash_init(cfg, jax.random.PRNGKey(1))
    x = jnp.asarray(rng.standard_normal((2, 1, cfg.d_model)),
                    jnp.float32)
    flag = jnp.asarray(True)
    with ops.use_impl(impl):
        y_c, view_c = attn.gqa_decode(cfg, p, w_h, x,
                                      cv.ContiguousView(cache), pos,
                                      flag)
        y_p, view_p = attn.gqa_decode(cfg, p, w_h, x,
                                      cv.PagedView(pool, bt), pos,
                                      flag)
        # raw-cache input returns a raw cache (container fidelity)
        y_r, cache_r = attn.gqa_decode(cfg, p, w_h, x, cache, pos, flag)
    assert isinstance(view_c, cv.ContiguousView)
    assert isinstance(view_p, cv.PagedView)
    assert isinstance(cache_r, kvcache.LayerKVCache)
    assert_array_equal(np.asarray(y_c), np.asarray(y_p))
    assert_array_equal(np.asarray(y_c), np.asarray(y_r))
    # the appended rows agree across layouts
    from repro.core import paged_cache
    phys = paged_cache.physical_rows(bt, pos, PAGE)
    got = paged_cache._flat(view_p.pool.k)[phys]
    want = jax.vmap(lambda kk, pp: kk[pp])(view_c.cache.k, pos)
    assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_gather_stats_paged_bit_exact(impl):
    """The SP stats corner, fast: view.gather_stats over a page pool is
    bit-identical to the contiguous stats over the same rows, under an
    arbitrary (ownership-style) mask including an all-masked row —
    tier-1 coverage for flash_decode_gathered_stats_paged /
    gather_decode_stats_pool_ref (the slow sweep only runs weekly)."""
    cfg = _gqa_cfg()
    cache, pool, bt, pos = _gqa_pair(cfg, seed=11)
    rng = np.random.default_rng(11)
    b, h_kv, d = 2, cfg.n_kv_heads, cfg.head_dim
    n_sel = 8                      # <= min valid rows (pos floor PAGE)
    nv = np.asarray(pos) + 1
    idx = np.stack([np.stack([
        rng.choice(nv[bi], size=n_sel, replace=False)
        for _ in range(h_kv)]) for bi in range(b)]).astype(np.int32)
    mask = rng.integers(0, 2, (b, h_kv, n_sel)).astype(bool)
    mask[0, 0] = False                                # all-masked row
    q = jnp.asarray(rng.standard_normal((b, cfg.n_heads, d)),
                    jnp.float32)
    with ops.use_impl(impl):
        got = cv.PagedView(pool, bt).gather_stats(
            q, jnp.asarray(idx), jnp.asarray(mask))
        want = cv.ContiguousView(cache).gather_stats(
            q, jnp.asarray(idx), jnp.asarray(mask))
        got_off = cv.OffloadedView(_offload_twin_gqa(pool),
                                   bt).gather_stats(
            q, jnp.asarray(idx), jnp.asarray(mask))
    for g_, w_, o_ in zip(got, want, got_off):
        assert_array_equal(np.asarray(g_), np.asarray(w_))
        assert_array_equal(np.asarray(o_), np.asarray(w_))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_mla_gather_latent_stats_paged_bit_exact(impl):
    cfg = _mla_cfg()
    cache, pool, bt, pos = _mla_pair(cfg, seed=12)
    rng = np.random.default_rng(12)
    m = cfg.mla
    b, n_sel = 2, 8                # <= min valid rows (pos floor PAGE)
    nv = np.asarray(pos) + 1
    idx = np.stack([rng.choice(nv[bi], size=n_sel, replace=False)
                    for bi in range(b)]).astype(np.int32)
    mask = rng.integers(0, 2, (b, n_sel)).astype(bool)
    mask[0] = False                                   # all-masked row
    q_lat = jnp.asarray(rng.standard_normal(
        (b, cfg.n_heads, m.kv_lora_rank + m.qk_rope_dim)), jnp.float32)
    kw = dict(lora_rank=m.kv_lora_rank,
              scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5,
              sel_mask=jnp.asarray(mask), return_stats=True)
    with ops.use_impl(impl):
        got = cv.PagedMLAView(pool, bt).gather_latent(
            q_lat, jnp.asarray(idx), **kw)
        want = cv.ContiguousMLAView(cache).gather_latent(
            q_lat, jnp.asarray(idx), **kw)
        got_off = cv.OffloadedMLAView(_offload_twin_mla(pool),
                                      bt).gather_latent(
            q_lat, jnp.asarray(idx), **kw)
    for g_, w_, o_ in zip(got, want, got_off):
        assert_array_equal(np.asarray(g_), np.asarray(w_))
        assert_array_equal(np.asarray(o_), np.asarray(w_))


def test_views_are_jit_transparent_pytrees():
    cfg = _gqa_cfg()
    cache, pool, bt, _ = _gqa_pair(cfg, seed=5)
    for view in (cv.ContiguousView(cache), cv.PagedView(pool, bt)):
        leaves, treedef = jax.tree_util.tree_flatten(view)
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        assert type(back) is type(view)
        out = jax.jit(lambda v: v.capacity
                      + jnp.int32(0) * leaves[0].ravel()[0].astype(
                          jnp.int32))(view)
        assert int(out) == view.capacity
    # coercion round trip
    assert isinstance(cv.as_gqa_view(cache), cv.ContiguousView)
    assert cv.unwrap(cv.as_gqa_view(cache)) is cache
    assert isinstance(cv.paged_view(pool, bt), cv.PagedView)
    # the offloaded pool dispatches through the same coercion — but
    # the resulting view is host-stateful, NOT a pytree
    opool = _offload_twin_gqa(pool)
    oview = cv.paged_view(opool, bt)
    assert isinstance(oview, cv.OffloadedView)
    assert cv.is_view(oview) and cv.unwrap(oview) is opool
    assert oview.capacity == cv.PagedView(pool, bt).capacity


def test_offloaded_view_rejects_traced_selection():
    """Jitting the offloaded gather would bake host state into the
    trace — the view must refuse with direction, not miscompute."""
    cfg = _gqa_cfg()
    _, pool, bt, pos = _gqa_pair(cfg, seed=14)
    view = cv.OffloadedView(_offload_twin_gqa(pool), bt)
    rng = np.random.default_rng(14)
    q = jnp.asarray(rng.standard_normal(
        (2, cfg.n_heads, cfg.head_dim)), jnp.float32)
    idx = jnp.zeros((2, cfg.n_kv_heads, 4), jnp.int32)
    sel = jnp.ones((2, cfg.n_kv_heads, 4), bool)
    with pytest.raises(TypeError, match="concrete"):
        jax.jit(lambda i: view.gather_decode(q, i, sel))(idx)


def test_offloaded_bytes_pcie_per_wave_property():
    """The PCIe ledger is exact, not estimated: every gather wave
    uploads precisely budget·2·d·itemsize bytes per kv head per
    request (K and V rows for the selected budget — full fetch every
    wave, no delta caching), and the A/B staging holds at most two
    waves' rows in HBM."""
    cfg = _gqa_cfg()
    _, pool, bt, pos = _gqa_pair(cfg, seed=15)
    opool = _offload_twin_gqa(pool)
    view = cv.OffloadedView(opool, bt)
    rng = np.random.default_rng(15)
    b, h_kv, d = 2, cfg.n_kv_heads, cfg.head_dim
    k_sel = 16
    q = jnp.asarray(rng.standard_normal((b, cfg.n_heads, d)),
                    jnp.float32)
    per_wave = 2 * b * h_kv * k_sel * d * 4          # K + V, f32
    with ops.use_impl("xla"):
        for wave in range(1, 6):
            idx = jnp.asarray(rng.integers(
                0, PAGE, (b, h_kv, k_sel)), jnp.int32)
            view.gather_decode(q, idx,
                               jnp.ones((b, h_kv, k_sel), bool))
            assert opool.pipeline.waves == wave
            assert opool.pipeline.bytes_up == wave * per_wave
            assert opool.pipeline.device_staged_bytes() == \
                min(wave, 2) * per_wave


# ===========================================================================
# 2 + 3. model level: prefill_chunk over views; shim fidelity
# ===========================================================================
@pytest.fixture(scope="module")
def qwen_model():
    cfg = _gqa_cfg(budget=16)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_prefill_chunk_contiguous_equals_paged_equals_monolithic(
        qwen_model):
    cfg, model, params = qwen_model
    rng = np.random.default_rng(6)
    t, chunk = 6, 8
    prompt = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)
    # monolithic
    caches = model.init_caches(1, t * PAGE, layout="list")
    want, _ = model.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            caches, jnp.int32(0))

    def run_chunks(views):
        logits = None
        for ctx in range(0, len(prompt), chunk):
            end = min(ctx + chunk, len(prompt))
            toks = np.zeros(chunk, np.int32)
            toks[:end - ctx] = prompt[ctx:end]
            logits, views = model.prefill_chunk(
                params, jnp.asarray(toks[None]), views, jnp.int32(ctx),
                jnp.int32(end - ctx - 1))
        return logits, views

    pools = model.init_paged_pools(t + 1, PAGE)
    bt = jnp.asarray(np.arange(1, t + 1, dtype=np.int32)[None])
    got_paged, _ = run_chunks([cv.paged_view(p_, bt) for p_ in pools])
    dense = model.init_caches(1, t * PAGE, layout="list")
    got_contig, _ = run_chunks(
        [cv.ContiguousView(c) for c in dense["stack"]])
    # both view layouts see identical rows and (on the xla impl)
    # identical chunking: bit-exact against each other...
    assert_array_equal(np.asarray(got_paged), np.asarray(got_contig))
    # ...and equal to the one-shot prefill up to blocking tolerance
    assert_allclose(np.asarray(got_paged), np.asarray(want), atol=1e-5,
                    rtol=1e-5)


def test_paged_shims_warn_and_match_view_api(qwen_model):
    cfg, model, params = qwen_model
    rng = np.random.default_rng(7)
    t = 6
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    bt = jnp.asarray(np.arange(1, t + 1, dtype=np.int32)[None])

    def chunked(step_fn):
        pools = model.init_paged_pools(t + 1, PAGE)
        logits, pools = step_fn(pools)
        return logits, pools

    toks = np.zeros(16, np.int32)
    toks[:len(prompt)] = prompt
    args = (jnp.asarray(toks[None]), jnp.int32(0),
            jnp.int32(len(prompt) - 1))
    with pytest.warns(DeprecationWarning, match="prefill_chunk_paged"):
        got_shim, pools_shim = chunked(
            lambda pools: model.prefill_chunk_paged(
                params, args[0], pools, bt, args[1], args[2]))
    views = [cv.paged_view(p_, bt)
             for p_ in model.init_paged_pools(t + 1, PAGE)]
    got_view, views = model.prefill_chunk(params, args[0], views,
                                          args[1], args[2])
    assert_array_equal(np.asarray(got_shim), np.asarray(got_view))

    lt = jnp.asarray([int(jnp.argmax(got_view[0]))], jnp.int32)
    pos = jnp.asarray([len(prompt)], jnp.int32)
    with pytest.warns(DeprecationWarning, match="decode_step_paged"):
        lg_shim, _ = model.decode_step_paged(params, lt, pools_shim, bt,
                                             pos)
    lg_view, _ = model.decode_step(params, lt, views, pos)
    assert_array_equal(np.asarray(lg_shim), np.asarray(lg_view))


def test_engine_truncation_fields_identical(qwen_model):
    """EngineBase retirement: both engines stamp the same terminal
    fields (truncated, t_done, stats) for an impossible prompt."""
    from repro.serving import PagedServingEngine, Request, ServingEngine
    cfg, model, params = qwen_model
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    dense = ServingEngine(model, params, max_batch=1, max_len=16)
    paged = PagedServingEngine(model, params, num_pages=16, page_size=8,
                               max_batch=1, max_len_pages=3)
    for eng in (dense, paged):
        [r] = eng.run([Request(prompt=prompt.copy(), max_new_tokens=4)])
        assert r.truncated and r.output == [] and r.t_done is not None
        assert eng.stats["truncated"] == 1


# ===========================================================================
# 4. windowed paged prefill page-skip
# ===========================================================================
@pytest.mark.parametrize("offs", [(0, 0), (17, 30), (37, 21), (40, 40)])
def test_windowed_paged_prefill_page_skip_bit_exact(offs):
    """With a window, flash_prefill_paged walks only the pages that can
    intersect the window band (grid cut + traced rebase) — bit-exact vs
    the unskipped full-width walk (the contiguous kernel at page
    blocking over the same logical rows)."""
    import importlib
    fa = importlib.import_module("repro.kernels.flash_attention")
    rng = np.random.default_rng(9)
    b, h, h_kv, d, t = 2, 4, 2, 16, 6
    sq, window = 8, 12
    s = t * PAGE
    # the skip must actually engage: fewer live pages than the table
    assert (sq + window - 2) // PAGE + 2 < t
    k = rng.standard_normal((b, s, h_kv, d)).astype(np.float32)
    v = rng.standard_normal((b, s, h_kv, d)).astype(np.float32)
    n_pages = b * t + 1
    perm = rng.permutation(n_pages - 1) + 1
    bt = perm.reshape(b, t).astype(np.int32)
    k_pool = np.zeros((n_pages, PAGE, h_kv, d), np.float32)
    v_pool = np.zeros((n_pages, PAGE, h_kv, d), np.float32)
    for bi in range(b):
        for ti in range(t):
            rows = slice(ti * PAGE, (ti + 1) * PAGE)
            k_pool[bt[bi, ti]] = k[bi, rows]
            v_pool[bt[bi, ti]] = v[bi, rows]
    q = rng.standard_normal((b, sq, h, d)).astype(np.float32)
    off = jnp.asarray(offs, jnp.int32)
    out_paged = fa.flash_prefill_paged(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(bt), off, window=window, interpret=True)
    out_full = fa.flash_prefill_batched(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), off,
        causal=True, window=window, block_k=PAGE, interpret=True)
    assert_array_equal(np.asarray(out_paged), np.asarray(out_full))


def test_windowed_paged_prefill_model_level(qwen_model):
    """Model-level: chunked prefill with a sliding window over pages
    equals the windowed monolithic prefill."""
    cfg, model, params = qwen_model
    cfg_w = dataclasses.replace(cfg, sliding_window=16)
    model_w = Model(cfg_w)
    rng = np.random.default_rng(10)
    t, chunk = 6, 8
    prompt = rng.integers(0, cfg.vocab_size, 29).astype(np.int32)
    caches = model_w.init_caches(1, t * PAGE, layout="list")
    want, _ = model_w.prefill(params,
                              {"tokens": jnp.asarray(prompt[None])},
                              caches, jnp.int32(0))
    pools = model_w.init_paged_pools(t + 1, PAGE)
    bt = jnp.asarray(np.arange(1, t + 1, dtype=np.int32)[None])
    views = [cv.paged_view(p_, bt) for p_ in pools]
    logits = None
    for ctx in range(0, len(prompt), chunk):
        end = min(ctx + chunk, len(prompt))
        toks = np.zeros(chunk, np.int32)
        toks[:end - ctx] = prompt[ctx:end]
        logits, views = model_w.prefill_chunk(
            params, jnp.asarray(toks[None]), views, jnp.int32(ctx),
            jnp.int32(end - ctx - 1))
    assert_allclose(np.asarray(logits), np.asarray(want), atol=1e-5,
                    rtol=1e-5)


# ===========================================================================
# 5. slow: ShardedView-over-pages ≡ contiguous SP ≡ single-device
# ===========================================================================
SP_VIEW_CODE = """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.core import cache_view as cv
from repro.core import hash_attention as ha
from repro.core.kvcache import LayerKVCache, MLACache
from repro.core.paged_cache import PagedKVPool, PagedMLAPool
from repro.distributed.decode import SPDecode
from repro.launch.mesh import make_mesh

n_sh, b, page, t_loc = 8, 2, 8, 4
s_loc = page * t_loc
s = n_sh * s_loc
mesh = make_mesh((8,), ("model",))
rng = np.random.default_rng(0)

# ---- GQA --------------------------------------------------------------
cfg = get_reduced("llama3-405b", d_model=64)
cfg = dataclasses.replace(cfg, dtype="float32", hata=dataclasses.replace(
    cfg.hata, budget_min=48, budget_max=48))
h, h_kv, d, rbit = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.hata.rbit
k = rng.standard_normal((b, s, h_kv, d)).astype(np.float32)
v = rng.standard_normal((b, s, h_kv, d)).astype(np.float32)
codes = rng.integers(0, 2**32, (b, s, h_kv, rbit // 32), dtype=np.uint32)
q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)), jnp.float32)
n_valid = jnp.int32(s - 5)
seq = NamedSharding(mesh, P(None, "model", None, None))
cache = LayerKVCache(k=jax.device_put(jnp.asarray(k), seq),
                     v=jax.device_put(jnp.asarray(v), seq),
                     codes=jax.device_put(jnp.asarray(codes), seq))
# paged twin: per-shard local pools + local block tables (local page ids)
p_loc = b * t_loc
k_pool = np.zeros((n_sh * p_loc, page, h_kv, d), np.float32)
v_pool = np.zeros_like(k_pool)
c_pool = np.zeros((n_sh * p_loc, page, h_kv, rbit // 32), np.uint32)
bt = np.zeros((b, n_sh * t_loc), np.int32)
for i in range(n_sh):
    perm = rng.permutation(p_loc)
    for bi in range(b):
        for j in range(t_loc):
            lp = perm[bi * t_loc + j]
            rows = slice(i * s_loc + j * page, i * s_loc + (j + 1) * page)
            k_pool[i * p_loc + lp] = k[bi, rows]
            v_pool[i * p_loc + lp] = v[bi, rows]
            c_pool[i * p_loc + lp] = codes[bi, rows]
            bt[bi, i * t_loc + j] = lp
ps = NamedSharding(mesh, P("model", None, None, None))
bs = NamedSharding(mesh, P(None, "model"))
pview = cv.PagedView(
    PagedKVPool(k=jax.device_put(jnp.asarray(k_pool), ps),
                v=jax.device_put(jnp.asarray(v_pool), ps),
                codes=jax.device_put(jnp.asarray(c_pool), ps)),
    jax.device_put(jnp.asarray(bt), bs))

def single(qq):
    budget = ha.clamped_budget(cfg.hata, s, None)
    top, idx, _ = ha.hata_score_select(
        qq, w, jnp.asarray(codes), rbit=rbit, budget=budget,
        n_valid=n_valid)
    return ha.hata_attend(
        qq, LayerKVCache(k=jnp.asarray(k), v=jnp.asarray(v),
                         codes=jnp.asarray(codes)), idx, top >= 0)
ref = np.asarray(jax.jit(single)(q))
for mode in ("two_stage", "local_split"):
    strat = SPDecode(mesh, seq_axes=("model",), mode=mode)
    out_c = np.asarray(jax.jit(lambda qq: strat.gqa(
        cfg, qq, w, cv.ContiguousView(cache), n_valid, True))(q))
    out_p = np.asarray(jax.jit(lambda qq: strat.gqa(
        cfg, qq, w, pview, n_valid, True))(q))
    assert np.array_equal(out_p, out_c), ("gqa", mode)
    if mode == "two_stage":
        assert float(np.abs(out_c - ref).max()) < 1e-4, "gqa two_stage"

# ---- MLA --------------------------------------------------------------
cfg = get_reduced("deepseek-v2-lite-16b", d_model=64)
cfg = dataclasses.replace(cfg, dtype="float32", hata=dataclasses.replace(
    cfg.hata, budget_min=48, budget_max=48))
m = cfg.mla
h, rbit = cfg.n_heads, cfg.hata.rbit
r, rd = m.kv_lora_rank, m.qk_rope_dim
ckv = rng.standard_normal((b, s, r)).astype(np.float32)
krope = rng.standard_normal((b, s, rd)).astype(np.float32)
codes = rng.integers(0, 2**32, (b, s, rbit // 32), dtype=np.uint32)
q_lat = jnp.asarray(rng.standard_normal((b, h, r + rd)), jnp.float32)
w = jnp.asarray(rng.standard_normal((1, r + rd, rbit)), jnp.float32)
p = {"wuv": jnp.asarray(
    rng.standard_normal((r, h * m.v_head_dim)), jnp.float32)}
seq3 = NamedSharding(mesh, P(None, "model", None))
cache = MLACache(ckv=jax.device_put(jnp.asarray(ckv), seq3),
                 krope=jax.device_put(jnp.asarray(krope), seq3),
                 codes=jax.device_put(jnp.asarray(codes), seq3))
c_pool = np.zeros((n_sh * p_loc, page, r), np.float32)
r_pool = np.zeros((n_sh * p_loc, page, rd), np.float32)
h_pool = np.zeros((n_sh * p_loc, page, rbit // 32), np.uint32)
bt = np.zeros((b, n_sh * t_loc), np.int32)
for i in range(n_sh):
    perm = rng.permutation(p_loc)
    for bi in range(b):
        for j in range(t_loc):
            lp = perm[bi * t_loc + j]
            rows = slice(i * s_loc + j * page, i * s_loc + (j + 1) * page)
            c_pool[i * p_loc + lp] = ckv[bi, rows]
            r_pool[i * p_loc + lp] = krope[bi, rows]
            h_pool[i * p_loc + lp] = codes[bi, rows]
            bt[bi, i * t_loc + j] = lp
ps3 = NamedSharding(mesh, P("model", None, None))
pview = cv.PagedMLAView(
    PagedMLAPool(ckv=jax.device_put(jnp.asarray(c_pool), ps3),
                 krope=jax.device_put(jnp.asarray(r_pool), ps3),
                 codes=jax.device_put(jnp.asarray(h_pool), ps3)),
    jax.device_put(jnp.asarray(bt), bs))
for mode in ("two_stage", "local_split"):
    strat = SPDecode(mesh, seq_axes=("model",), mode=mode)
    out_c = np.asarray(jax.jit(lambda qq: strat.mla(
        cfg, p, w, qq, cv.ContiguousMLAView(cache), n_valid, True))(q_lat))
    out_p = np.asarray(jax.jit(lambda qq: strat.mla(
        cfg, p, w, qq, pview, n_valid, True))(q_lat))
    assert np.array_equal(out_p, out_c), ("mla", mode)
print("SPVIEW-OK")
"""


@pytest.mark.slow
def test_sp_paged_view_matches_contiguous_and_single():
    from conftest import run_subprocess
    out = run_subprocess(SP_VIEW_CODE, n_devices=8, timeout=900)
    assert "SPVIEW-OK" in out
