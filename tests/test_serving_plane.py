"""Serving plane (DESIGN.md §8): admission lookahead, async waves,
disaggregated transfer, centralized timing.

The plane's contract is that scheduling NEVER changes tokens: every
configuration (async double-buffering, lookahead admission,
disaggregated pools, preemption storms) must be bit-exact against the
colocated synchronous engine, which in turn matches offline decode.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import Model
from repro.serving import (ADMIT, DEFER, TRUNCATE, AdmissionController,
                           PagedServingEngine, Request, ServingEngine)
from tests.conftest import run_subprocess


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(get_reduced("qwen1.5-0.5b"),
                              dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, seed, n, *, plen_lo=6, plen_hi=16, new_tokens=6):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(
                        0, cfg.vocab_size,
                        int(rng.integers(plen_lo, plen_hi))
                    ).astype(np.int32),
                    max_new_tokens=new_tokens, id=i) for i in range(n)]


def _outputs(done):
    return {r.id: (list(r.output), r.truncated)
            for r in done}


def _offline(model, params, prompt, n_new, max_len=96):
    caches = model.init_caches(1, max_len, layout="list")
    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, caches,
        jnp.int32(0))
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt) + model.cfg.meta_tokens
    for _ in range(n_new - 1):
        logits, caches = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), caches,
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


# ---------------------------------------------------------------------------
# AdmissionController unit tests
# ---------------------------------------------------------------------------
def _fake_req(rid):
    return Request(prompt=np.zeros(4, np.int32), max_new_tokens=1,
                   id=rid)


def test_admission_fcfs_at_zero_lookahead():
    ac = AdmissionController(lookahead=0)
    a, b = _fake_req(0), _fake_req(1)
    ac.submit(a), ac.submit(b)
    # head defers -> nothing admits, even though b would
    assert ac.select(lambda r: DEFER if r is a else ADMIT) is None
    assert list(ac.queue) == [a, b]
    req, verdict = ac.select(lambda r: ADMIT)
    assert req is a and verdict == ADMIT
    assert a.t_admitted is not None


def test_admission_lookahead_first_fit_in_window():
    ac = AdmissionController(lookahead=1)
    a, b, c = _fake_req(0), _fake_req(1), _fake_req(2)
    for r in (a, b, c):
        ac.submit(r)
    # head defers, window reaches b: first-fit admits b, a stays first
    req, verdict = ac.select(lambda r: DEFER if r is a else ADMIT)
    assert req is b and verdict == ADMIT
    assert list(ac.queue) == [a, c]
    # c sits OUTSIDE the window of 2 when a and b both defer
    ac.requeue(b)
    assert ac.select(lambda r: ADMIT if r is c else DEFER) is None
    assert list(ac.queue) == [b, a, c]    # requeue goes to the front


def test_admission_truncate_pops_like_admit():
    ac = AdmissionController(lookahead=2)
    a, b = _fake_req(0), _fake_req(1)
    ac.submit(a), ac.submit(b)
    req, verdict = ac.select(
        lambda r: TRUNCATE if r is a else ADMIT)
    assert req is a and verdict == TRUNCATE
    assert list(ac.queue) == [b]


# ---------------------------------------------------------------------------
# async waves == sync, every engine flavor
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sample", ["greedy", "top_p"])
def test_dense_async_matches_sync(qwen, sample):
    cfg, model, params = qwen
    ref = ServingEngine(model, params, max_batch=2, max_len=48,
                        sample=sample).run(_reqs(cfg, 3, 5))
    got = ServingEngine(model, params, max_batch=2, max_len=48,
                        sample=sample,
                        async_waves=True).run(_reqs(cfg, 3, 5))
    assert _outputs(got) == _outputs(ref)


@pytest.mark.parametrize("sample", ["greedy", "top_p"])
def test_paged_async_matches_sync(qwen, sample):
    cfg, model, params = qwen
    kw = dict(num_pages=32, page_size=8, max_batch=2, prefill_chunk=8,
              sample=sample)
    ref = PagedServingEngine(model, params, **kw).run(_reqs(cfg, 4, 6))
    eng = PagedServingEngine(model, params, async_waves=True, **kw)
    got = eng.run(_reqs(cfg, 4, 6))
    assert _outputs(got) == _outputs(ref)
    eng.alloc.check()


def test_offload_async_matches_sync(qwen):
    cfg, model, params = qwen
    kw = dict(num_pages=32, page_size=8, max_batch=2, prefill_chunk=8,
              offload=True)
    ref = PagedServingEngine(model, params, **kw).run(_reqs(cfg, 5, 4))
    got = PagedServingEngine(model, params, async_waves=True,
                             **kw).run(_reqs(cfg, 5, 4))
    assert _outputs(got) == _outputs(ref)


# ---------------------------------------------------------------------------
# preemption storms under open-loop arrivals
# ---------------------------------------------------------------------------
def _storm(model, cfg, params, *, async_waves, sample):
    """Tight pool + staggered submits: admissions race decode growth,
    forcing preempt/replay while waves may be in flight."""
    eng = PagedServingEngine(model, params, num_pages=9, page_size=8,
                             max_batch=3, prefill_chunk=8,
                             prefix_sharing=False, sample=sample,
                             async_waves=async_waves)
    reqs = _reqs(cfg, 15, 6, plen_lo=10, plen_hi=14, new_tokens=16)
    done = []
    for i, r in enumerate(reqs):       # open-loop: one submit per tick
        eng.submit(r)
        done.extend(eng.step())
    guard = 0
    while len(done) < len(reqs):
        done.extend(eng.step())
        guard += 1
        assert guard < 10_000
    eng.alloc.check()
    return eng, done


@pytest.mark.parametrize("sample", ["greedy", "top_p"])
def test_preemption_storm_async_matches_sync(qwen, sample):
    cfg, model, params = qwen
    ref_eng, ref = _storm(model, cfg, params, async_waves=False,
                          sample=sample)
    got_eng, got = _storm(model, cfg, params, async_waves=True,
                          sample=sample)
    assert ref_eng.stats["preemptions"] >= 1, "storm did not storm"
    assert got_eng.stats["preemptions"] >= 1
    assert _outputs(got) == _outputs(ref)
    if sample == "greedy":             # and the tokens are REAL ones
        for r in ref:
            assert r.output == _offline(model, params, r.prompt,
                                        16), r.id


# ---------------------------------------------------------------------------
# lookahead relieves head-of-line blocking
# ---------------------------------------------------------------------------
def _hol_run(model, cfg, params, lookahead):
    eng = PagedServingEngine(model, params, num_pages=12, page_size=8,
                             max_batch=2, max_len_pages=10,
                             prefill_chunk=8, prefix_sharing=False,
                             lookahead=lookahead)
    rng = np.random.default_rng(21)
    long_r = Request(prompt=rng.integers(0, cfg.vocab_size, 24,
                                         dtype=np.int32),
                     max_new_tokens=24, id=0)
    # 66 tokens -> 9 pages: MORE than the 8 free while long_r lives
    # (always DEFER), exactly fitting once long_r drains — and small
    # enough that nobody is ever preempted (preemption would restamp
    # t_admitted at re-admission and break the order assertions)
    big = Request(prompt=rng.integers(0, cfg.vocab_size, 66,
                                      dtype=np.int32),
                  max_new_tokens=4, id=1)
    small = Request(prompt=rng.integers(0, cfg.vocab_size, 8,
                                        dtype=np.int32),
                    max_new_tokens=4, id=2)
    eng.submit(long_r)
    done = []
    while long_r.slot < 0:             # long_r live before the others
        done.extend(eng.step())        # join the queue
    eng.submit(big)
    eng.submit(small)
    guard = 0
    while len(done) < 3:
        done.extend(eng.step())
        guard += 1
        assert guard < 10_000
    eng.alloc.check()
    for r in done:
        assert r.output == _offline(model, params, r.prompt,
                                    r.max_new_tokens), r.id
        assert not r.truncated
        assert r.preemptions == 0      # t_admitted must be single-stamp
    by_id = {r.id: r for r in done}
    return by_id


def test_lookahead_bypasses_head_of_line(qwen):
    cfg, model, params = qwen
    fcfs = _hol_run(model, cfg, params, lookahead=0)
    # strict FCFS: the oversized prompt (DEFERred while long_r holds
    # the pool) blocks the small admissible one behind it
    assert fcfs[1].t_admitted < fcfs[2].t_admitted
    la = _hol_run(model, cfg, params, lookahead=1)
    # first-fit window: small admits while big keeps deferring, and
    # big still completes once the pool frees (no starvation)
    assert la[2].t_admitted < la[1].t_admitted
    assert la[2].t_done < la[1].t_done
    # lookahead never changes tokens, only admission order
    for rid in (0, 1, 2):
        assert la[rid].output == fcfs[rid].output


# ---------------------------------------------------------------------------
# disaggregated prefill/decode == colocated
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("async_waves", [False, True])
def test_disaggregated_matches_colocated(qwen, async_waves):
    cfg, model, params = qwen
    kw = dict(num_pages=32, page_size=8, max_batch=2, prefill_chunk=8)
    ref = PagedServingEngine(model, params, **kw).run(_reqs(cfg, 6, 5))
    eng = PagedServingEngine(model, params, disaggregate=True,
                             prefill_pages=24,
                             async_waves=async_waves, **kw)
    got = eng.run(_reqs(cfg, 6, 5))
    assert _outputs(got) == _outputs(ref)
    assert eng.stats["pages_shipped"] > 0
    eng.decode_group.alloc.check()
    eng.prefill_group.alloc.check()


# ---------------------------------------------------------------------------
# config-zoo parity: MoE + GQA + sliding window through the engine
# ---------------------------------------------------------------------------
def test_mixtral_engine_matches_offline_greedy():
    """Reduced mixtral-8x22b (MoE top-2, GQA, SWA) served on the paged
    engine matches offline prefill+decode token-for-token. Runs
    DROPLESS (capacity_factor = n_experts / top_k): the engine's
    chunked prefill and the offline loop group tokens into different
    expert batches, which is only bit-identical when no token can drop
    — the same precondition the speculative verify wave documents
    (DESIGN.md §9 exclusion table)."""
    cfg = dataclasses.replace(get_reduced("mixtral-8x22b"),
                              dtype="float32")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe,
        capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _reqs(cfg, 11, 4, new_tokens=6)
    eng = PagedServingEngine(model, params, num_pages=32, page_size=8,
                             max_batch=2, prefill_chunk=8)
    done = eng.run(reqs)
    eng.alloc.check()
    got = _outputs(done)
    for r in _reqs(cfg, 11, 4, new_tokens=6):
        ref = _offline(model, params, r.prompt, r.max_new_tokens)
        assert got[r.id] == (ref, False), f"req {r.id}"


# ---------------------------------------------------------------------------
# centralized timing stamps
# ---------------------------------------------------------------------------
def test_request_timing_stamped_once(qwen):
    cfg, model, params = qwen
    eng = PagedServingEngine(model, params, num_pages=32, page_size=8,
                             max_batch=2, prefill_chunk=8)
    done = eng.run(_reqs(cfg, 7, 4, new_tokens=5))
    for r in done:
        assert len(r.t_tokens) == len(r.output)
        assert r.t_first_token == r.t_tokens[0]
        assert r.t_submit <= r.t_admitted <= r.t_tokens[0]
        assert all(a <= b for a, b in zip(r.t_tokens, r.t_tokens[1:]))
        assert r.t_tokens[-1] <= r.t_done


# ---------------------------------------------------------------------------
# sharded-pool decode waves (multi-device, subprocess)
# ---------------------------------------------------------------------------
def test_sharded_pool_engine_matches_colocated_subprocess():
    run_subprocess("""
import dataclasses
import jax, numpy as np
from repro.configs import get_reduced
from repro.launch.mesh import make_mesh
from repro.models import Model
from repro.serving import PagedServingEngine, Request

cfg = dataclasses.replace(get_reduced("qwen1.5-0.5b"),
                          dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(9)
def reqs():
    rng = np.random.default_rng(9)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, 14,
                                        dtype=np.int32),
                    max_new_tokens=6, id=i) for i in range(4)]
kw = dict(num_pages=32, page_size=8, max_batch=2, prefill_chunk=8)
ref = PagedServingEngine(model, params, **kw).run(reqs())
mesh = make_mesh((4,), ("model",))
eng = PagedServingEngine(model, params, mesh=mesh,
                         sp_mode="two_stage", **kw)
got = eng.run(reqs())
r = {q.id: list(q.output) for q in ref}
g = {q.id: list(q.output) for q in got}
assert g == r, (g, r)
eng.alloc.check()
print("OK")
""", n_devices=4)
