import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet in a fresh process with N host devices.

    Device count is locked at first jax init, so multi-device tests must
    run out-of-process (pytest's main process keeps 1 device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True,
                         timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\n"
            f"STDERR:\n{res.stderr[-4000:]}")
    return res.stdout
