"""Budget-table schema discipline + the one resolver + engine parity.

A malformed table must hard-error (``BudgetTableError``) — never fall
back silently to the global budget. A table whose entries reproduce the
config's own clamp must be a bit-exact no-op through a full serving
decode (the engine installs the table at trace time).
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import budgets
from repro.core.budgets import BudgetTable, BudgetTableError
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.training.calibrate import _allocate


def _tbl(**over):
    obj = {
        "version": 1,
        "model": "x",
        "n_layers": 4,
        "n_kv_heads": 2,
        "layers": [
            {"layer": 1, "budget_frac": 0.1, "budget_min": 8,
             "budget_max": 64, "head_recall": {"0": 0.5, "1": 0.75}},
            {"layer": 2, "budget_frac": 0.25, "budget_min": 4,
             "budget_max": 32},
        ],
    }
    obj.update(over)
    return obj


def _entry(**over):
    e = {"layer": 3, "budget_frac": 0.1, "budget_min": 8,
         "budget_max": 64}
    e.update(over)
    return e


def test_valid_table_parses():
    t = budgets.parse_budget_table(_tbl())
    assert t.n_layers == 4 and t.layers() == [1, 2]


@pytest.mark.parametrize("obj", [
    [],                                        # not an object
    _tbl(version=2),                           # bad version
    _tbl(version="1"),                         # stringly version
    _tbl(extra=1),                             # unknown top-level key
    _tbl(n_layers=0),                          # non-positive n_layers
    _tbl(n_layers=True),                       # bool masquerading as int
    _tbl(n_kv_heads=0),
    _tbl(layers={}),                           # layers not a list
    _tbl(layers=[[]]),                         # entry not an object
    _tbl(layers=[_entry(layer=4)]),            # layer out of range
    _tbl(layers=[_entry(), _entry()]),         # duplicate layer
    _tbl(layers=[_entry(layer=True)]),
    _tbl(layers=[{"layer": 1}]),               # missing keys
    _tbl(layers=[_entry(oops=1)]),             # unknown entry key
    _tbl(layers=[_entry(budget_frac=0.0)]),
    _tbl(layers=[_entry(budget_frac=1.5)]),
    _tbl(layers=[_entry(budget_frac=True)]),
    _tbl(layers=[_entry(budget_min=0)]),
    _tbl(layers=[_entry(budget_min=2.5)]),
    _tbl(layers=[_entry(budget_min=32, budget_max=16)]),
    _tbl(layers=[_entry(head_recall=[0.5])]),  # not an object
    _tbl(layers=[_entry(head_recall={"x": 0.5})]),
    _tbl(layers=[_entry(head_recall={"2": 0.5})]),  # head >= n_kv_heads
    _tbl(layers=[_entry(head_recall={"0": 1.5})]),
    _tbl(layers=[_entry(head_recall={"0": True})]),
])
def test_malformed_tables_hard_error(obj):
    with pytest.raises(BudgetTableError):
        budgets.parse_budget_table(obj)


def test_load_errors_are_budget_table_errors(tmp_path):
    with pytest.raises(BudgetTableError, match="not found"):
        budgets.load_budget_table(str(tmp_path / "missing.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{nope")
    with pytest.raises(BudgetTableError, match="invalid JSON"):
        budgets.load_budget_table(str(bad))
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_tbl()))
    assert budgets.load_budget_table(str(good)).layers() == [1, 2]


# ---------------------------------------------------------------------------
# the one resolver
# ---------------------------------------------------------------------------
def _hcfg():
    return get_reduced("qwen1.5-0.5b").hata


def test_resolver_without_table_is_global():
    hcfg = _hcfg()
    for s in (8, 64, 512, 4096):
        assert budgets.resolve_budget(hcfg, s) == min(hcfg.budget(s), s)


def test_uniform_table_matches_global_budget():
    """Entries restating the config clamp resolve identically."""
    hcfg = _hcfg()
    obj = {"version": 1, "n_layers": 2, "layers": [
        {"layer": l, "budget_frac": hcfg.budget_frac,
         "budget_min": hcfg.budget_min, "budget_max": hcfg.budget_max}
        for l in range(2)]}
    with budgets.use_budget_table(budgets.parse_budget_table(obj)):
        for l in range(2):
            for s in (8, 64, 512, 4096):
                assert budgets.resolve_budget(hcfg, s, layer=l) \
                    == budgets.resolve_budget(hcfg, s)


def test_table_overrides_per_layer_and_none_falls_back():
    hcfg = _hcfg()
    obj = {"version": 1, "n_layers": 3, "layers": [
        {"layer": 1, "budget_frac": 0.5, "budget_min": 4,
         "budget_max": 8}]}
    with budgets.use_budget_table(budgets.parse_budget_table(obj)):
        assert budgets.resolve_budget(hcfg, 64, layer=1) == 8
        # unlisted layer and layer=None (scanned/SP paths) -> global
        assert budgets.resolve_budget(hcfg, 64, layer=0) \
            == hcfg.budget(64)
        assert budgets.resolve_budget(hcfg, 64) == hcfg.budget(64)
        # window still caps
        assert budgets.resolve_budget(hcfg, 64, layer=1, window=5) == 5
    assert budgets.get_budget_table() is None


def test_env_table_applies_and_explicit_wins(tmp_path, monkeypatch):
    hcfg = _hcfg()
    p = tmp_path / "t.json"
    p.write_text(json.dumps({"version": 1, "n_layers": 2, "layers": [
        {"layer": 0, "budget_frac": 0.5, "budget_min": 2,
         "budget_max": 4}]}))
    monkeypatch.setenv(budgets.ENV_TABLE, str(p))
    budgets.clear_table_cache()
    try:
        assert budgets.resolve_budget(hcfg, 64, layer=0) == 4
        explicit = BudgetTable(n_layers=2, entries=((0, 0.5, 6, 6),))
        with budgets.use_budget_table(explicit):
            assert budgets.resolve_budget(hcfg, 64, layer=0) == 6
        assert budgets.resolve_budget(hcfg, 64, layer=0) == 4
    finally:
        monkeypatch.delenv(budgets.ENV_TABLE)
        budgets.clear_table_cache()


# ---------------------------------------------------------------------------
# engine parity: uniform table == no table, bit-exact decode
# ---------------------------------------------------------------------------
def test_engine_decode_bit_exact_with_uniform_table():
    cfg = get_reduced("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    hcfg = cfg.hata
    obj = {"version": 1, "model": cfg.name, "n_layers": cfg.n_layers,
           "layers": [
               {"layer": l, "budget_frac": hcfg.budget_frac,
                "budget_min": hcfg.budget_min,
                "budget_max": hcfg.budget_max}
               for l in range(cfg.n_layers)]}
    table = budgets.parse_budget_table(obj)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]

    def run(budget_table):
        # fresh engine per run: budgets resolve at trace time
        eng = ServingEngine(model, params, max_batch=2, max_len=64,
                            budget_table=budget_table)
        done = eng.run([Request(prompt=p, max_new_tokens=6)
                        for p in prompts])
        return {r.prompt.tobytes(): r.output for r in done}

    assert run(None) == run(table)


def test_engine_rejects_malformed_table_path(tmp_path):
    cfg = get_reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"version": 7}))
    with pytest.raises(BudgetTableError):
        ServingEngine(model, params, max_batch=1, max_len=32,
                      budget_table=str(bad))


# ---------------------------------------------------------------------------
# the joint allocator
# ---------------------------------------------------------------------------
def test_allocate_finds_strictly_lower_budget():
    """Heterogeneous slopes: a saturated layer sheds budget that a
    steep layer only partly re-spends."""
    ladder = [8, 12, 16, 20]
    curves = {0: [0.80, 0.90, 0.905, 0.91],
              1: [0.20, 0.50, 0.80, 0.95]}
    gi = ladder.index(16)
    idx = _allocate(curves, ladder, gi)
    total = sum(ladder[idx[l]] for l in curves)
    recall = sum(curves[l][idx[l]] for l in curves)
    target = sum(curves[l][gi] for l in curves)
    assert recall >= target - 1e-12
    assert total < 2 * 16


def test_allocate_homogeneous_never_exceeds_global():
    ladder = [8, 12, 16, 20]
    curves = {l: [0.5, 0.6, 0.7, 0.8] for l in range(3)}
    gi = ladder.index(16)
    idx = _allocate(curves, ladder, gi)
    assert sum(ladder[idx[l]] for l in curves) <= 3 * 16
    assert sum(curves[l][idx[l]] for l in curves) \
        >= sum(curves[l][gi] for l in curves) - 1e-12
