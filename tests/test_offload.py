"""HATA-off (KV offloading with hash prefetch) — exactness + cost model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from repro.configs.base import HataConfig
from repro.core import kvcache
from repro.core.hash_attention import hata_decode, hata_prefill
from repro.core.offload import (OffloadPlatform, OffloadedKV,
                                hata_off_decode_time,
                                magicpig_decode_time)

RNG = np.random.default_rng(0)
HCFG = HataConfig(rbit=64, budget_min=8, budget_max=16, budget_frac=0.1)


def test_offloaded_decode_matches_in_memory():
    B, H, Hkv, d, S = 2, 4, 2, 32, 64
    w = jnp.asarray(RNG.standard_normal((Hkv, d, HCFG.rbit)),
                    jnp.float32) / np.sqrt(d)
    kp = RNG.standard_normal((B, 40, Hkv, d)).astype(np.float32)
    vp = RNG.standard_normal((B, 40, Hkv, d)).astype(np.float32)
    q = jnp.asarray(RNG.standard_normal((B, H, d)), jnp.float32)
    k1 = RNG.standard_normal((B, 1, Hkv, d)).astype(np.float32)
    v1 = RNG.standard_normal((B, 1, Hkv, d)).astype(np.float32)

    off = OffloadedKV(B, S, Hkv, d, HCFG.rbit)
    off.append(kp, vp, w)
    got = off.decode_step(q, k1, v1, w, HCFG)

    cache = kvcache.init_kv_cache(B, S, Hkv, d, rbit=HCFG.rbit,
                                  dtype=jnp.float32)
    qs = jnp.asarray(RNG.standard_normal((B, 40, H, d)), jnp.float32)
    _, cache = hata_prefill(qs, jnp.asarray(kp), jnp.asarray(vp), w,
                            cache, hcfg=HCFG, pos=jnp.int32(0))
    res = hata_decode(q, jnp.asarray(k1), jnp.asarray(v1), w, cache,
                      hcfg=HCFG, pos=jnp.int32(40))
    assert_allclose(np.asarray(got), np.asarray(res.out), atol=1e-5)


def test_offload_pcie_accounting():
    B, Hkv, d, S = 1, 2, 16, 64
    off = OffloadedKV(B, S, Hkv, d, 64)
    kp = RNG.standard_normal((B, 32, Hkv, d)).astype(np.float32)
    off.append(kp, kp, jnp.asarray(
        RNG.standard_normal((Hkv, d, 64)), jnp.float32))
    before = off.bytes_pcie
    assert before == 2 * kp.nbytes


def test_cost_model_hata_off_beats_magicpig():
    """Table 3's direction: trained 128-bit hashing + GPU attention +
    PCIe prefetch beats 1500-bit LSH + CPU attention."""
    plat = OffloadPlatform()
    for s in (36_000, 72_000, 131_072):
        t_h = hata_off_decode_time(s, 128, 8, 4, budget=max(
            512, int(0.0156 * s)), rbit=128, plat=plat)
        t_m = magicpig_decode_time(s, 128, 8, 4, plat=plat)
        assert t_h < t_m, (s, t_h, t_m)
