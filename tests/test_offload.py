"""HATA-off (KV offloading with hash prefetch) — exactness + cost model.

Three layers of guarantee:

  * the seed **simulator** (:class:`OffloadedKV`) matches the in-memory
    ``hata_decode`` — its selection path is the shared batched pipeline
    (static ``clamped_budget``, ``aggregate_q_codes``, ``mask_scores``);
  * the tiered **``OffloadedView``** is differential-tested against the
    simulator as oracle (bit-identical selection, matching outputs) and
    bit-exact against the all-resident ``PagedView`` at 64k rows with
    <10% of K/V device-resident (the acceptance bar; 1M in the slow
    sweep);
  * the **serving engine**'s offload mode replays preemptions exactly
    and matches the all-resident paged engine token-for-token.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose, assert_array_equal

from repro.configs.base import HataConfig
from repro.core import cache_view as cv
from repro.core import hash_attention as ha
from repro.core import kvcache
from repro.core.hash_attention import hata_decode, hata_prefill
from repro.core.offload import (OffloadPlatform, OffloadedKV,
                                hata_off_decode_time,
                                hata_resident_decode_time,
                                init_offloaded_kv_pool,
                                magicpig_decode_time)
from repro.core.topk import chunked_topk
from repro.kernels import ops

RNG = np.random.default_rng(0)
HCFG = HataConfig(rbit=64, budget_min=8, budget_max=16, budget_frac=0.1)


def test_offloaded_decode_matches_in_memory():
    B, H, Hkv, d, S = 2, 4, 2, 32, 64
    w = jnp.asarray(RNG.standard_normal((Hkv, d, HCFG.rbit)),
                    jnp.float32) / np.sqrt(d)
    kp = RNG.standard_normal((B, 40, Hkv, d)).astype(np.float32)
    vp = RNG.standard_normal((B, 40, Hkv, d)).astype(np.float32)
    q = jnp.asarray(RNG.standard_normal((B, H, d)), jnp.float32)
    k1 = RNG.standard_normal((B, 1, Hkv, d)).astype(np.float32)
    v1 = RNG.standard_normal((B, 1, Hkv, d)).astype(np.float32)

    off = OffloadedKV(B, S, Hkv, d, HCFG.rbit)
    off.append(kp, vp, w)
    got = off.decode_step(q, k1, v1, w, HCFG)

    cache = kvcache.init_kv_cache(B, S, Hkv, d, rbit=HCFG.rbit,
                                  dtype=jnp.float32)
    qs = jnp.asarray(RNG.standard_normal((B, 40, H, d)), jnp.float32)
    _, cache = hata_prefill(qs, jnp.asarray(kp), jnp.asarray(vp), w,
                            cache, hcfg=HCFG, pos=jnp.int32(0))
    res = hata_decode(q, jnp.asarray(k1), jnp.asarray(v1), w, cache,
                      hcfg=HCFG, pos=jnp.int32(40))
    assert_allclose(np.asarray(got), np.asarray(res.out), atol=1e-5)


def test_offload_pcie_accounting():
    B, Hkv, d, S = 1, 2, 16, 64
    off = OffloadedKV(B, S, Hkv, d, 64)
    kp = RNG.standard_normal((B, 32, Hkv, d)).astype(np.float32)
    off.append(kp, kp, jnp.asarray(
        RNG.standard_normal((Hkv, d, 64)), jnp.float32))
    before = off.bytes_pcie
    assert before == 2 * kp.nbytes


def test_cost_model_hata_off_beats_magicpig():
    """Table 3's direction: trained 128-bit hashing + GPU attention +
    PCIe prefetch beats 1500-bit LSH + CPU attention."""
    plat = OffloadPlatform()
    for s in (36_000, 72_000, 131_072):
        t_h = hata_off_decode_time(s, 128, 8, 4, budget=max(
            512, int(0.0156 * s)), rbit=128, plat=plat)
        t_m = magicpig_decode_time(s, 128, 8, 4, plat=plat)
        assert t_h < t_m, (s, t_h, t_m)


def test_cost_model_overlap_hides_pcie_behind_decode():
    """The double-buffered schedule: with the layer's weight streaming
    on the device side of the wave (decode is weight-bound), the PCIe
    upload of the next wave's budget hides behind it — offload decode
    lands within ~1.3x of all-resident at long context."""
    plat = OffloadPlatform()
    d, n_kv, g, rbit = 128, 8, 4, 128
    layer_bytes = 405e6                      # ~70B-class layer, bf16
    for s in (262_144, 1_048_576):
        budget = min(4096, max(512, int(0.0156 * s)))
        kw = dict(budget=budget, rbit=rbit, plat=plat,
                  layer_bytes=layer_bytes)
        t_serial = hata_off_decode_time(s, d, n_kv, g, **kw)
        t_overlap = hata_off_decode_time(s, d, n_kv, g, overlap=True,
                                         **kw)
        t_resident = hata_resident_decode_time(s, d, n_kv, g, **kw)
        assert t_overlap < t_serial
        assert t_overlap <= 1.3 * t_resident, (s, t_overlap, t_resident)


def test_rbit_must_be_packable():
    """Satellite: rbit % 32 != 0 used to silently drop hash bits at
    every encode (rbit // 32 words); now it fails at construction."""
    with pytest.raises(ValueError, match="multiple of 32"):
        OffloadedKV(1, 8, 1, 16, 48)
    with pytest.raises(ValueError, match="multiple of 32"):
        init_offloaded_kv_pool(2, 8, 1, 16, rbit=40)
    with pytest.raises(ValueError, match="multiple of 32"):
        HataConfig(rbit=48)
    with pytest.raises(ValueError, match="multiple of 32"):
        HataConfig(rbit=0)


def test_simulator_budget_is_static_and_window_masked():
    """Satellite: the simulator's budget comes from the static capacity
    via ``clamped_budget`` (one trace, one selection shape — not the
    drifting ``min(budget(pos), pos)``), and a sliding window masks its
    score path like everywhere else in the stack."""
    B, H, Hkv, d, S = 1, 2, 1, 16, 64
    w = jnp.asarray(RNG.standard_normal((Hkv, d, 64)),
                    jnp.float32) / np.sqrt(d)
    kp = RNG.standard_normal((B, 30, Hkv, d)).astype(np.float32)
    q = jnp.asarray(RNG.standard_normal((B, H, d)), jnp.float32)
    k1 = RNG.standard_normal((B, 1, Hkv, d)).astype(np.float32)
    window = 8
    hcfg = dataclasses.replace(HCFG, budget_min=16, budget_max=16)
    off = OffloadedKV(B, S, Hkv, d, 64)
    off.append(kp, kp, w)
    got = off.decode_step(q, k1, k1, w, hcfg, window=window)
    # reference: dense softmax over exactly the last ``window`` rows
    # (budget clamps to the window, so selection covers it fully)
    rows = np.concatenate([kp, k1], axis=1)[:, -window:]
    qf = np.asarray(q).reshape(B, Hkv, H // Hkv, d) * (d ** -0.5)
    logits = np.einsum("bhgd,bkhd->bhgk", qf,
                       rows.astype(np.float64))
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhgk,bkhd->bhgd", p, rows.astype(np.float64))
    assert_allclose(np.asarray(got),
                    want.reshape(B, H, d).astype(np.float32), atol=1e-5)


def _fill_tiered_pair(b, s, h_kv, d, rbit, page, seed=0):
    """A PagedView and an OffloadedView over identical rows (shuffled
    pages, page 0 scratch), built directly at pool granularity so it
    scales to 64k+ rows."""
    from repro.core import offload, paged_cache
    rng = np.random.default_rng(seed)
    t = s // page
    n_pages = b * t + 1
    k = rng.standard_normal((n_pages, page, h_kv, d)).astype(np.float32)
    v = rng.standard_normal((n_pages, page, h_kv, d)).astype(np.float32)
    codes = rng.integers(0, 2 ** 32, (n_pages, page, h_kv, rbit // 32),
                         dtype=np.uint32)
    perm = rng.permutation(n_pages - 1) + 1
    bt = jnp.asarray(perm.reshape(b, t).astype(np.int32))
    pool = paged_cache.PagedKVPool(k=jnp.asarray(k), v=jnp.asarray(v),
                                   codes=jnp.asarray(codes))
    opool = init_offloaded_kv_pool(n_pages, page, h_kv, d, rbit=rbit)
    opool = dataclasses.replace(opool, codes=pool.codes)
    opool.host.k[...] = k
    opool.host.v[...] = v
    return cv.PagedView(pool, bt), cv.OffloadedView(opool, bt), bt


def _one_wave(view, q, w, hcfg, n_valid, rbit, h_kv):
    q_codes = ha.aggregate_q_codes(q, w, h_kv)
    scores = view.hamming_scores(q_codes, n_valid, rbit=rbit)
    budget = ha.clamped_budget(hcfg, view.capacity, None)
    top, idx = chunked_topk(scores, budget)
    return idx, view.gather_decode(q, idx, top >= 0)


def test_offloaded_view_matches_simulator_oracle():
    """The tiered view against the seed simulator as oracle: same
    shared selection pipeline -> bit-identical top-k rows; reference
    einsum vs fused gathered kernel -> matching outputs."""
    B, H, Hkv, d, page, T = 2, 4, 2, 32, 8, 8
    S = page * T
    rbit = HCFG.rbit
    w = jnp.asarray(RNG.standard_normal((Hkv, d, rbit)),
                    jnp.float32) / np.sqrt(d)
    kp = RNG.standard_normal((B, 40, Hkv, d)).astype(np.float32)
    vp = RNG.standard_normal((B, 40, Hkv, d)).astype(np.float32)
    q = jnp.asarray(RNG.standard_normal((B, H, d)), jnp.float32)
    k1 = RNG.standard_normal((B, 1, Hkv, d)).astype(np.float32)
    v1 = RNG.standard_normal((B, 1, Hkv, d)).astype(np.float32)

    sim = OffloadedKV(B, S, Hkv, d, rbit)
    sim.append(kp, vp, w)
    got_sim = sim.decode_step(q, k1, v1, w, HCFG)

    pool = init_offloaded_kv_pool(B * T + 1, page, Hkv, d, rbit=rbit)
    bt = jnp.asarray(
        np.arange(1, B * T + 1, dtype=np.int32).reshape(B, T))
    view = cv.OffloadedView(pool, bt)
    all_k = np.concatenate([kp, k1], axis=1)
    all_v = np.concatenate([vp, v1], axis=1)
    codes = ops.hash_encode_heads(jnp.asarray(all_k), w)
    for b in range(B):
        v1b = cv.OffloadedView(view.unwrap(), bt[b:b + 1])
        v1b = v1b.append_chunk(jnp.asarray(all_k[b:b + 1]),
                               jnp.asarray(all_v[b:b + 1]),
                               codes[b:b + 1], jnp.int32(0))
        view = cv.OffloadedView(v1b.unwrap(), bt)

    q_codes = ha.aggregate_q_codes(q, w, Hkv)
    scores_v = view.hamming_scores(q_codes, jnp.int32(41), rbit=rbit)
    scores_s = ha.mask_scores(
        ops.hamming_scores(q_codes, sim.codes, rbit=rbit), sim.pos)
    assert_array_equal(np.asarray(scores_v),
                       np.asarray(scores_s)[:, :, :view.capacity])
    budget = ha.clamped_budget(HCFG, view.capacity, None)
    assert budget == ha.clamped_budget(HCFG, sim.codes.shape[1], None)
    top, idx = chunked_topk(scores_v, budget)
    _, idx_sim = chunked_topk(scores_s, budget)
    assert_array_equal(np.asarray(idx), np.asarray(idx_sim))
    out = view.gather_decode(q, idx, top >= 0)
    assert_allclose(np.asarray(out), np.asarray(got_sim), atol=1e-5)


def test_offloaded_view_64k_low_residency_bit_exact():
    """Acceptance: a 64k-row context decodes through the tiered view
    with <10% of K/V bytes device-resident, bit-exact vs the
    all-resident PagedView."""
    b, h_kv, d, page, rbit = 1, 1, 32, 2048, 32
    s = 65_536
    hcfg = HataConfig(rbit=rbit, budget_min=512, budget_max=1024,
                      budget_frac=0.0156)
    pview, oview, bt = _fill_tiered_pair(b, s, h_kv, d, rbit, page,
                                         seed=42)
    rng = np.random.default_rng(42)
    g = 4
    q = jnp.asarray(rng.standard_normal((b, h_kv * g, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)),
                    jnp.float32) / np.sqrt(d)
    n_valid = jnp.int32(s - 17)
    with ops.use_impl("xla"):
        for wave in range(2):            # fill both staging slots
            idx_p, out_p = _one_wave(pview, q, w, hcfg, n_valid, rbit,
                                     h_kv)
            idx_o, out_o = _one_wave(oview, q, w, hcfg, n_valid, rbit,
                                     h_kv)
            assert_array_equal(np.asarray(idx_p), np.asarray(idx_o))
            assert_array_equal(np.asarray(out_p), np.asarray(out_o))
    pipe = oview.pool.pipeline
    resident = (oview.pool.hbm_resident_bytes()
                + pipe.device_staged_bytes())
    assert resident < 0.10 * oview.pool.host.nbytes, (
        resident, oview.pool.host.nbytes)
    # full fetch every wave: budget rows x (K + V) x d x 4 bytes
    budget = ha.clamped_budget(hcfg, pview.capacity, None)
    assert pipe.bytes_up == 2 * (2 * b * h_kv * budget * d * 4)
    assert pipe.waves == 2


@pytest.mark.slow
def test_offloaded_view_1m_low_residency_bit_exact():
    """The slow-sweep scale point: 1M rows, same contract."""
    b, h_kv, d, page, rbit = 1, 1, 16, 4096, 32
    s = 1_048_576
    hcfg = HataConfig(rbit=rbit, budget_min=512, budget_max=4096,
                      budget_frac=0.0156)
    pview, oview, bt = _fill_tiered_pair(b, s, h_kv, d, rbit, page,
                                         seed=7)
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((b, 4 * h_kv, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)),
                    jnp.float32) / np.sqrt(d)
    n_valid = jnp.int32(s - 1)
    with ops.use_impl("xla"):
        idx_p, out_p = _one_wave(pview, q, w, hcfg, n_valid, rbit, h_kv)
        idx_o, out_o = _one_wave(oview, q, w, hcfg, n_valid, rbit, h_kv)
    assert_array_equal(np.asarray(idx_p), np.asarray(idx_o))
    assert_array_equal(np.asarray(out_p), np.asarray(out_o))
    resident = (oview.pool.hbm_resident_bytes()
                + oview.pool.pipeline.device_staged_bytes())
    assert resident < 0.10 * oview.pool.host.nbytes


def test_offload_engine_matches_paged_with_preemption():
    """Serving-level acceptance: the offload pool mode emits the same
    tokens as the all-resident paged engine under a pool tight enough
    to preempt, and the replay is exact."""
    from repro.configs import get_reduced
    from repro.models import Model
    from repro.serving import PagedServingEngine, Request
    cfg = get_reduced("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
               for _ in range(3)]

    def run(**kw):
        eng = PagedServingEngine(model, params, num_pages=9,
                                 page_size=8, max_batch=3,
                                 prefill_chunk=8, prefix_sharing=False,
                                 **kw)
        done = eng.run([Request(prompt=p.copy(), max_new_tokens=16)
                        for p in prompts])
        return eng, {tuple(r.prompt.tolist()): list(r.output)
                     for r in done}

    base_eng, base = run()
    off_eng, off = run(offload=True)
    assert base_eng.stats["preemptions"] >= 1
    assert off_eng.stats["preemptions"] >= 1
    assert base == off
    assert off_eng.stats["bytes_pcie"] > 0
    off_eng.alloc.check()
