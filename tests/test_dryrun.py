"""Dry-run machinery on a small forced-device mesh (fast CI stand-in for
the 512-device production run; the full 40-cell results live in
experiments/dryrun/ + EXPERIMENTS.md)."""
import json

import pytest

from conftest import run_subprocess

CODE = """
import os, json
import jax
from repro.launch import hlo_cost

# tiny production-mesh stand-in exercised through the same lower_cell path
import repro.launch.dryrun as dr
import repro.launch.mesh as mesh_mod

def small_mesh(*, multi_pod=False):
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro.launch.mesh import make_mesh
    return make_mesh(shape, axes)

mesh_mod.make_production_mesh = small_mesh
dr.make_production_mesh = small_mesh

import dataclasses
import repro.configs.registry as reg
from repro.configs import get_reduced

# shrink shapes so the reduced configs lower quickly
import repro.configs.base as base
small = {
    "train_4k": base.ShapeConfig("train_4k", 64, 8, "train"),
    "prefill_32k": base.ShapeConfig("prefill_32k", 64, 4, "prefill"),
    "decode_32k": base.ShapeConfig("decode_32k", 64, 8, "decode"),
}
dr.get_shape = lambda name: small[name]
_orig_get_config = dr.get_config
dr.get_config = lambda a: get_reduced(a)

for arch in ["qwen1.5-0.5b", "deepseek-v2-lite-16b", "mamba2-130m"]:
    for shape in ["train_4k", "prefill_32k", "decode_32k"]:
        for multi in (False, True):
            rec = dr.lower_cell(arch, shape, multi_pod=multi)
            assert rec["ok"], (arch, shape, multi, rec.get("error"))
            assert rec["hlo_cost"]["flops"] > 0
            if shape != "train_4k":
                pass
print("DRYRUN-OK")
"""


@pytest.mark.slow
def test_dryrun_cells_small_mesh():
    out = run_subprocess(CODE, n_devices=8, timeout=1200)
    assert "DRYRUN-OK" in out


def test_hlo_cost_parser_counts_loop_trips():
    code = """
import jax, jax.numpy as jnp
from repro.launch.hlo_cost import analyze

def f(x, w):
    def body(x, wi):
        return jnp.tanh(x @ wi), None
    return jax.lax.scan(body, x, w)[0]

c = jax.jit(f).lower(
    jax.ShapeDtypeStruct((128, 128), jnp.float32),
    jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)).compile()
cost = analyze(c.as_text())
analytic = 2 * 128 ** 3 * 8
assert 0.9 < cost.flops / analytic < 1.2, cost.flops / analytic
print("PARSER-OK")
"""
    out = run_subprocess(code, n_devices=1, timeout=300)
    assert "PARSER-OK" in out
