"""HBM-residency vs decode latency for the tiered offload path.

Two parts, both printed as ``name,us_per_call,derived`` CSV:

  * **Measured** (this container): real ``OffloadedView`` decode waves
    over a 64k-row host pool at device residencies around 5% / 10%
    (the budget and the two staged waves set residency; resident codes
    are the floor), against the all-resident ``PagedView`` at the same
    budget. Reports tokens/s and the PCIe ledger (exact bytes, from
    ``PrefetchPipeline`` — not an estimate). Wall-clock here is a CPU
    XLA proxy; the contract being demonstrated is bit-exactness + the
    byte accounting, not device speed.
  * **Cost model** (Table 3 accounting at 1M rows): serial
    (score -> PCIe -> attend) vs double-buffered overlap
    (``t_score + max(t_pcie, t_dev)``) vs all-resident. The overlap
    point must land within 1.3x of all-resident — the PR's acceptance
    bar — because decode is weight-streaming-bound and the budget
    upload hides behind the layer's weight traffic.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.base import HataConfig
from repro.core import cache_view as cv
from repro.core import hash_attention as ha
from repro.core import paged_cache
from repro.core.offload import (OffloadPlatform, hata_off_decode_time,
                                hata_resident_decode_time,
                                init_offloaded_kv_pool)
from repro.core.topk import chunked_topk
from repro.kernels import ops

S, PAGE, H_KV, G, D, RBIT = 65_536, 2048, 1, 4, 32, 32
WAVES = 8


def _build_pair(seed=0):
    rng = np.random.default_rng(seed)
    t = S // PAGE
    n_pages = t + 1
    k = rng.standard_normal((n_pages, PAGE, H_KV, D)).astype(np.float32)
    v = rng.standard_normal((n_pages, PAGE, H_KV, D)).astype(np.float32)
    codes = rng.integers(0, 2 ** 32, (n_pages, PAGE, H_KV, RBIT // 32),
                         dtype=np.uint32)
    bt = jnp.asarray((rng.permutation(t) + 1).reshape(1, t)
                     .astype(np.int32))
    pool = paged_cache.PagedKVPool(k=jnp.asarray(k), v=jnp.asarray(v),
                                   codes=jnp.asarray(codes))
    opool = init_offloaded_kv_pool(n_pages, PAGE, H_KV, D, rbit=RBIT)
    opool = dataclasses.replace(opool, codes=pool.codes)
    opool.host.k[...] = k
    opool.host.v[...] = v
    return cv.PagedView(pool, bt), cv.OffloadedView(opool, bt)


def _waves(view, q, w, budget):
    hcfg = HataConfig(rbit=RBIT, budget_min=budget, budget_max=budget)
    n_valid = jnp.int32(S - 3)
    out = None
    t0 = time.perf_counter()
    for _ in range(WAVES):
        q_codes = ha.aggregate_q_codes(q, w, H_KV)
        scores = view.hamming_scores(q_codes, n_valid, rbit=RBIT)
        b_ = ha.clamped_budget(hcfg, view.capacity, None)
        top, idx = chunked_topk(scores, b_)
        out = view.gather_decode(q, idx, top >= 0)
        out.block_until_ready()
    return out, (time.perf_counter() - t0) / WAVES


def run_measured():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, H_KV * G, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((H_KV, D, RBIT)),
                    jnp.float32) / np.sqrt(D)
    rows = []
    with ops.use_impl("xla"):
        for budget in (1024, 2816):          # ~5% / ~10% residency
            pview, oview = _build_pair()
            out_p, dt_p = _waves(pview, q, w, budget)
            out_o, dt_o = _waves(oview, q, w, budget)
            exact = bool(jnp.all(out_p == out_o))
            pipe = oview.pool.pipeline
            resident = (oview.pool.hbm_resident_bytes()
                        + pipe.device_staged_bytes())
            rows.append({
                "budget": budget,
                "residency": resident / oview.pool.host.nbytes,
                "tok_s_resident": 1.0 / dt_p,
                "tok_s_offload": 1.0 / dt_o,
                "pcie_mb_per_tok": pipe.bytes_up / WAVES / 2 ** 20,
                "bit_exact": exact,
            })
    return rows


def run_cost_model():
    """1M-row accounting at a 70B-class layer (d=128, 8 kv heads,
    ~405MB of bf16 layer weights streamed per decode step)."""
    plat = OffloadPlatform()
    s, d, n_kv, g, rbit = 1_048_576, 128, 8, 4, 128
    budget = 4096
    layer = 405e6
    kw = dict(budget=budget, rbit=rbit, plat=plat, layer_bytes=layer)
    t_serial = hata_off_decode_time(s, d, n_kv, g, **kw)
    t_overlap = hata_off_decode_time(s, d, n_kv, g, overlap=True, **kw)
    t_resident = hata_resident_decode_time(s, d, n_kv, g, **kw)
    codes_bytes = s * n_kv * rbit / 8
    staged = 2 * budget * n_kv * 2 * d * 2
    residency = (codes_bytes + staged) / (s * n_kv * 2 * d * 2)
    return {"serial_us": t_serial * 1e6, "overlap_us": t_overlap * 1e6,
            "resident_us": t_resident * 1e6,
            "ratio": t_overlap / t_resident, "residency": residency}


def main():
    for r in run_measured():
        tag = f"offload_eff/64k_b{r['budget']}"
        print(f"{tag}/residency,0,{r['residency'] * 100:.1f}")
        print(f"{tag}/tok_s_offload,0,{r['tok_s_offload']:.2f}")
        print(f"{tag}/tok_s_resident,0,{r['tok_s_resident']:.2f}")
        print(f"{tag}/pcie_mb_per_tok,0,{r['pcie_mb_per_tok']:.3f}")
        print(f"{tag}/bit_exact,0,{int(r['bit_exact'])}")
        assert r["bit_exact"], "offload parity broke"
        assert r["residency"] < 0.11, r["residency"]
    cm = run_cost_model()
    print(f"offload_eff/1m/serial_us,{cm['serial_us']:.0f},0")
    print(f"offload_eff/1m/overlap_us,{cm['overlap_us']:.0f},0")
    print(f"offload_eff/1m/resident_us,{cm['resident_us']:.0f},0")
    print(f"offload_eff/1m/overlap_ratio,0,{cm['ratio']:.3f}")
    print(f"offload_eff/1m/residency,0,{cm['residency'] * 100:.1f}")
    # the PR acceptance bar: double-buffered offload within 1.3x of
    # all-resident at <10% residency
    assert cm["ratio"] <= 1.3, cm
    assert cm["residency"] < 0.10, cm
    return True


if __name__ == "__main__":
    main()
