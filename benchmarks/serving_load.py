"""Serving-plane load test: mixed open/closed-loop request streams.

Drives the paged serving engine the way a frontend would — a Poisson
open-loop arrival stream (requests land on the queue at wall-clock
times, whatever the engine's backlog) mixed with closed-loop users
(each submits its next request the moment the previous one finishes) —
and reports the latency/throughput quartet that serving work actually
optimizes:

  * sustained decode throughput (tokens/s over the busy window),
  * TTFT P50/P99 (first token stamp - submit),
  * ITL  P50/P99 (inter-token gaps from the per-token ``t_tokens``
    stamps the engine records on the ONE emission path),

for the synchronous and async double-buffered tick. Per-token host
work (detokenize/HTTP-flush stand-in: ``--host-work-us`` of sleep in
the ``on_token`` hook) is what the async tick is designed to hide —
it overlaps the next wave's device time, so async throughput exceeds
sync by up to (host + device) / max(host, device). Outputs are
bit-exact between the two ticks (checked every run): the speedup is
pure scheduling.

Independent capacity scaling: ``--disaggregate`` splits the pools and
``--prefill-pages`` scales the prefill side alone (decode keeps
``--num-pages``) — the knob pair a role-split deployment tunes
independently.

CI: ``--assert-speedup R`` fails the run if async/sync tokens/s < R;
``--baseline benchmarks/data/serving_baseline.json --assert-baseline F``
fails if async tokens/s drops below F x the committed number.

Speculative mode: ``--speculate D`` switches the run to a plain-vs-
speculative throughput comparison (DESIGN.md §9). The model is the
reduced arch DEEPENED to ``--spec-layers`` with every layer past
``--spec-draft-layers`` made a residual no-op (``wo``/``wd`` zeroed),
so the layer-subset draft computes the full model's exact logits —
acceptance is ~100% and the measured speedup is the round structure
itself (one fused draft+verify dispatch commits depth+1 tokens where
the plain engine dispatches one wave per token), not draft luck.
Outputs are asserted identical; ``--assert-spec-speedup R`` gates
spec/plain tokens/s, ``--spec-baseline`` + ``--write-spec-baseline``
track the committed number in benchmarks/data/.

The comparison runs at ``--spec-batch`` (default 1), NOT the load
test's ``--max-batch``: speculation trades extra verify FLOPs for
fewer decode waves, so it wins exactly when a wave's cost is
dominated by fixed per-wave overhead (small batch — the
latency-bound regime; on an accelerator, the memory-bound one) and
loses when the backend is compute-saturated (batch 8 on this CPU
container measures 0.82x). Gating at batch 1 measures the regime
the subsystem is FOR; the compute-bound crossover is documented in
EXPERIMENTS.md rather than gated.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List

import numpy as np


@dataclasses.dataclass
class _Workload:
    open_reqs: List          # (arrival_s, Request) sorted by arrival
    closed_seed_reqs: List   # one initial Request per closed user
    closed_followups: Dict   # user id -> list of follow-up Requests


def _build_workload(cfg, *, n_open, open_rate, n_users, turns,
                    prompt_len, new_tokens, seed):
    """Deterministic workload: prompts/ids/arrival offsets are a pure
    function of the seed, so sync and async runs serve IDENTICAL
    requests (matched outputs are asserted, not assumed)."""
    from repro.serving import Request
    rng = np.random.default_rng(seed)

    def make(rid):
        plen = int(rng.integers(max(2, prompt_len // 2), prompt_len + 1))
        return Request(
            prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=new_tokens, id=rid)

    arrivals = np.cumsum(rng.exponential(1.0 / open_rate, n_open))
    open_reqs = [(float(t), make(10_000 + i))
                 for i, t in enumerate(arrivals)]
    closed_seed = [make(20_000 + u * 100) for u in range(n_users)]
    followups = {20_000 + u * 100:
                 [make(20_000 + u * 100 + k) for k in range(1, turns)]
                 for u in range(n_users)}
    return _Workload(open_reqs, closed_seed, followups)


def _drive(engine, wl: _Workload):
    """Run the engine against the stream: open-loop requests submit at
    their wall-clock arrival time, closed-loop users resubmit on
    completion. Returns finished requests + the busy-window wall time."""
    pending = list(wl.open_reqs)
    followups = {k: list(v) for k, v in wl.closed_followups.items()}
    total = len(pending) + len(wl.closed_seed_reqs) \
        + sum(len(v) for v in followups.values())
    for r in wl.closed_seed_reqs:
        engine.submit(r)
    done = []
    t0 = time.monotonic()
    guard = 0
    while len(done) < total:
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            engine.submit(pending.pop(0)[1])
        finished = engine.step()
        for r in finished:
            user = (r.id // 100) * 100
            if user in followups and followups[user]:
                engine.submit(followups[user].pop(0))
        done.extend(finished)
        if not finished and pending and not engine.queue \
                and all(s is None for s in engine.slots) \
                and not engine.prefill.busy:
            # idle gap before the next open-loop arrival: sleep to it
            # instead of spinning compiled no-op ticks
            time.sleep(max(0.0, min(pending[0][0] - now, 0.05)))
        guard += 1
        assert guard < 500_000, "load driver livelock"
    return done, time.monotonic() - t0


def _metrics(done, wall_s) -> Dict:
    ttft = np.asarray([r.t_tokens[0] - r.t_submit for r in done
                       if r.t_tokens]) * 1e3
    itl = np.concatenate([np.diff(r.t_tokens) for r in done
                          if len(r.t_tokens) > 1]) * 1e3
    toks = sum(len(r.output) for r in done)
    pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
    return {
        "requests": len(done),
        "tokens_out": toks,
        "wall_s": round(wall_s, 3),
        "tokens_per_s": round(toks / wall_s, 2),
        "ttft_ms": {"p50": round(pct(ttft, 50), 1),
                    "p99": round(pct(ttft, 99), 1)},
        "itl_ms": {"p50": round(pct(itl, 50), 1),
                   "p99": round(pct(itl, 99), 1)},
    }


def _spec_bench(args) -> Dict:
    """Plain vs speculative serving throughput on a deepened reduced
    model whose tail layers are residual no-ops (see module docstring:
    the layer-subset draft is then EXACT, acceptance ~100%)."""
    import dataclasses as dc

    import jax

    from repro.configs import get_reduced
    from repro.models import Model
    from repro.serving import (LayerSubsetDraft, PagedServingEngine,
                               Request, SpeculationController)

    cfg = dc.replace(get_reduced(args.arch), dtype="float32",
                     n_layers=args.spec_layers)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    nd = args.spec_draft_layers
    st = params["stack"]
    st = dict(st,
              attn=dict(st["attn"],
                        wo=st["attn"]["wo"].at[nd:].set(0.0)),
              ffn=dict(st["ffn"],
                       wd=st["ffn"]["wd"].at[nd:].set(0.0)))
    params = dict(params, stack=st)

    def mk_reqs():
        rng = np.random.default_rng(args.seed)
        return [Request(prompt=rng.integers(
                            0, cfg.vocab_size, args.prompt_len,
                            dtype=np.int32),
                        max_new_tokens=args.new_tokens, id=30_000 + i)
                for i in range(args.open_requests)]

    # block table sized to the workload (prompt + generation + spec
    # lookahead), not the whole pool: logical capacity drives the
    # per-wave hash-scoring work, and an oversized table would charge
    # both paths for rows no request ever writes
    table_pages = -(-(args.prompt_len + args.new_tokens
                      + args.speculate + 1) // args.page_size)

    def run(speculate):
        eng = PagedServingEngine(
            model, params, num_pages=args.num_pages,
            page_size=args.page_size, max_batch=args.spec_batch,
            max_len_pages=table_pages,
            prefill_chunk=2 * args.page_size, speculate=speculate)
        eng.run([Request(prompt=np.zeros(args.prompt_len, np.int32),
                         max_new_tokens=2, id=99_998)])     # warm jit
        reqs = mk_reqs()
        t0 = time.monotonic()
        done = eng.run(reqs)
        wall = time.monotonic() - t0
        toks = sum(len(r.output) for r in done)
        m = {"tokens_out": toks, "wall_s": round(wall, 3),
             "tokens_per_s": round(toks / wall, 2)}
        if speculate is not None:
            # draft hit-rate: committed tokens minus each (slot, round)
            # pair's guaranteed verify pick (= sum of the histogram),
            # over tokens drafted
            drafted = max(eng.stats["spec_drafted"], 1)
            hits = (eng.stats["spec_accepted"]
                    - sum(eng.stats["spec_acc_hist"]))
            m["spec_rounds"] = eng.stats["spec_rounds"]
            m["acceptance"] = round(max(hits, 0) / drafted, 3)
            m["acc_hist"] = list(eng.stats["spec_acc_hist"])
        eng.alloc.check()
        return m, {r.id: list(r.output) for r in done}

    spec = SpeculationController(
        depth=args.speculate, draft=LayerSubsetDraft(n_layers=nd))
    plain_m, plain_out = run(None)
    spec_m, spec_out = run(spec)
    assert plain_out == spec_out, (
        "speculative outputs diverged from plain greedy serving — "
        "speculation must never change tokens")
    speedup = spec_m["tokens_per_s"] / max(plain_m["tokens_per_s"],
                                           1e-9)
    result = {"plain": plain_m, "spec": spec_m,
              "depth": args.speculate, "draft_layers": nd,
              "model_layers": args.spec_layers,
              "speedup": round(speedup, 3), "outputs_matched": True}
    print(f"serving_load,spec_plain,tok_s={plain_m['tokens_per_s']}")
    print(f"serving_load,spec,tok_s={spec_m['tokens_per_s']},"
          f"accept={spec_m['acceptance']},"
          f"rounds={spec_m['spec_rounds']},"
          f"hist={spec_m['acc_hist']}")
    print(f"serving_load,spec_speedup,spec_over_plain="
          f"{result['speedup']}")
    if args.json:
        print(json.dumps(result, indent=2))
    if args.write_spec_baseline:
        os.makedirs(os.path.dirname(args.spec_baseline), exist_ok=True)
        with open(args.spec_baseline, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.spec_baseline}")
    if args.assert_spec_speedup is not None:
        assert speedup >= args.assert_spec_speedup, (
            f"spec/plain speedup {speedup:.3f} < required "
            f"{args.assert_spec_speedup} (plain "
            f"{plain_m['tokens_per_s']} tok/s, spec "
            f"{spec_m['tokens_per_s']} tok/s, acceptance "
            f"{spec_m['acceptance']})")
    return result


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--open-requests", type=int, default=8)
    ap.add_argument("--open-rate", type=float, default=8.0,
                    help="Poisson arrivals per second (open loop)")
    ap.add_argument("--users", type=int, default=4,
                    help="closed-loop users")
    ap.add_argument("--turns", type=int, default=2,
                    help="requests per closed-loop user")
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--host-work-us", type=int, default=1_200,
                    help="per-token host work (detok/HTTP stand-in) "
                         "the async tick should hide under the wave")
    ap.add_argument("--lookahead", type=int, default=0)
    ap.add_argument("--disaggregate", action="store_true")
    ap.add_argument("--prefill-pages", type=int, default=None,
                    help="with --disaggregate: prefill-side pool size "
                         "(decode keeps --num-pages)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--assert-speedup", type=float, default=None,
                    help="fail unless async/sync tokens_per_s >= R")
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "data",
                                         "serving_baseline.json"))
    ap.add_argument("--assert-baseline", type=float, default=None,
                    help="fail unless async tokens_per_s >= F x the "
                         "committed baseline")
    ap.add_argument("--write-baseline", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="print the metrics dict as JSON")
    ap.add_argument("--speculate", type=int, default=0,
                    help="speculative depth: switch to the plain-vs-"
                         "spec comparison (0 = the async/sync load "
                         "test)")
    ap.add_argument("--spec-layers", type=int, default=6,
                    help="with --speculate: deepen the reduced arch to "
                         "this many layers (tail layers become "
                         "residual no-ops)")
    ap.add_argument("--spec-batch", type=int, default=1,
                    help="with --speculate: engine batch for BOTH "
                         "sides of the comparison (small = the "
                         "latency-bound regime speculation targets; "
                         "see module docstring)")
    ap.add_argument("--spec-draft-layers", type=int, default=2,
                    help="with --speculate: the layer-subset draft "
                         "runs this many leading layers (the rest are "
                         "zeroed, so the draft is exact)")
    ap.add_argument("--spec-baseline",
                    default=os.path.join(os.path.dirname(__file__),
                                         "data",
                                         "serving_spec_baseline.json"))
    ap.add_argument("--assert-spec-speedup", type=float, default=None,
                    help="with --speculate: fail unless spec/plain "
                         "tokens_per_s >= R")
    ap.add_argument("--write-spec-baseline", action="store_true")
    args = ap.parse_args(argv)
    if args.speculate > 0:
        return _spec_bench(args)

    import dataclasses as dc

    import jax

    from repro.configs import get_reduced
    from repro.models import Model
    from repro.serving import PagedServingEngine

    cfg = dc.replace(get_reduced(args.arch), dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    host_work_s = args.host_work_us * 1e-6

    def run(async_waves: bool):
        wl = _build_workload(
            cfg, n_open=args.open_requests, open_rate=args.open_rate,
            n_users=args.users, turns=args.turns,
            prompt_len=args.prompt_len, new_tokens=args.new_tokens,
            seed=args.seed)
        eng = PagedServingEngine(
            model, params, num_pages=args.num_pages,
            page_size=args.page_size, max_batch=args.max_batch,
            prefill_chunk=2 * args.page_size,
            lookahead=args.lookahead, async_waves=async_waves,
            disaggregate=args.disaggregate,
            prefill_pages=args.prefill_pages,
            on_token=(lambda req, tok: time.sleep(host_work_s))
            if host_work_s > 0 else None)
        # warm the jit caches outside the measured window (paged
        # prefill is fixed-chunk-shaped, so one request compiles every
        # step fn the stream will use) — the load numbers measure the
        # serving schedule, not XLA compile time
        from repro.serving import Request
        eng.run([Request(
            prompt=np.zeros(args.prompt_len, np.int32),
            max_new_tokens=2, id=99_999)])
        done, wall = _drive(eng, wl)
        m = _metrics(done, wall)
        m["mode"] = "async" if async_waves else "sync"
        m["preemptions"] = eng.stats["preemptions"]
        m["truncated"] = eng.stats["truncated"]
        if args.disaggregate:
            m["pages_shipped"] = eng.stats["pages_shipped"]
        eng.alloc.check()
        return m, {r.id: list(r.output) for r in done}

    sync_m, sync_out = run(async_waves=False)
    async_m, async_out = run(async_waves=True)
    assert sync_out == async_out, (
        "async outputs diverged from sync — scheduling must never "
        "change tokens")
    speedup = async_m["tokens_per_s"] / max(sync_m["tokens_per_s"],
                                            1e-9)
    result = {"sync": sync_m, "async": async_m,
              "speedup": round(speedup, 3),
              "outputs_matched": True}

    for m in (sync_m, async_m):
        print(f"serving_load,{m['mode']},tok_s={m['tokens_per_s']},"
              f"ttft_p50_ms={m['ttft_ms']['p50']},"
              f"ttft_p99_ms={m['ttft_ms']['p99']},"
              f"itl_p50_ms={m['itl_ms']['p50']},"
              f"itl_p99_ms={m['itl_ms']['p99']},"
              f"preempt={m['preemptions']}")
    print(f"serving_load,speedup,async_over_sync={result['speedup']}")
    if args.json:
        print(json.dumps(result, indent=2))

    if args.write_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline}")
    if args.assert_speedup is not None:
        assert speedup >= args.assert_speedup, (
            f"async/sync speedup {speedup:.3f} < required "
            f"{args.assert_speedup} (sync {sync_m['tokens_per_s']} "
            f"tok/s, async {async_m['tokens_per_s']} tok/s)")
    if args.assert_baseline is not None:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = args.assert_baseline * base["async"]["tokens_per_s"]
        assert async_m["tokens_per_s"] >= floor, (
            f"async throughput {async_m['tokens_per_s']} tok/s fell "
            f"below {args.assert_baseline} x baseline "
            f"({base['async']['tokens_per_s']} tok/s)")
    return result


if __name__ == "__main__":
    main()
