"""Paper Tables 1-2 proxy: top-k selection recall per method on a real
(trained) model's q/k. Selection recall is the quantity the LongBench /
RULER accuracies are downstream of — recall 1.0 reproduces exact top-k
attention outputs bit-for-bit."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import harvested_layer, trained_hash
from repro.core import baselines, hashing, topk
from repro.data.hash_dataset import harvest_qk


def run(budget_frac: float = 0.1, rbit: int = 64):
    cfg, model, params, layer, batches = harvested_layer(-1)
    w, qh, kh = trained_hash(-1, rbit)
    b, s, h, d = qh.shape
    h_kv = kh.shape[2]
    g = h // h_kv
    budget = max(4, int(budget_frac * s))
    rows = []
    key = jax.random.PRNGKey(0)
    w_lsh = hashing.random_projection_lsh(key, d, rbit)
    w_lsh_big = hashing.random_projection_lsh(key, d, rbit * 8)
    for hi in range(h_kv):
        keys = jnp.asarray(kh[0, :, hi])
        qs = jnp.asarray(qh[0, s // 2:, hi * g:(hi + 1) * g])  # (Nq,G,d)
        true = jax.vmap(lambda qq: baselines.exact_scores(qq, keys))(qs)
        # method scores
        loki = baselines.loki_fit(keys, r=max(4, d // 4))
        quest = baselines.quest_fit(keys, block=8)
        from repro.kernels import ops
        kc_hata = ops.hash_encode(keys, w[hi])
        kc_lsh = ops.hash_encode(keys, w_lsh)
        kc_lsh_big = ops.hash_encode(keys, w_lsh_big)

        def recall_of(score_fn):
            est = jax.vmap(score_fn)(qs)
            return float(topk.selection_recall(
                est.astype(jnp.float32), true, budget).mean())

        rows.append({
            "head": hi,
            "exact-topk": 1.0,
            "hata": recall_of(lambda qq: baselines.lsh_scores(
                qq, kc_hata, w[hi], rbit).astype(jnp.float32)),
            f"lsh-{rbit}b": recall_of(lambda qq: baselines.lsh_scores(
                qq, kc_lsh, w_lsh, rbit).astype(jnp.float32)),
            f"lsh-{rbit * 8}b": recall_of(
                lambda qq: baselines.lsh_scores(
                    qq, kc_lsh_big, w_lsh_big,
                    rbit * 8).astype(jnp.float32)),
            "loki": recall_of(lambda qq: baselines.loki_scores(
                qq, loki, r=max(4, d // 4))),
            "quest": recall_of(lambda qq: baselines.quest_scores(
                qq, quest, block=8, s=s)),
        })
    out = {k: float(np.mean([r[k] for r in rows]))
           for k in rows[0] if k != "head"}
    return out


def main():
    out = run()
    for k, v in out.items():
        print(f"recall_accuracy/{k},0,{v:.4f}")
    return out


if __name__ == "__main__":
    main()
