"""Recall-vs-budget frontier + weekly recall gate (hash subsystem).

Runs the full harvest -> train -> calibrate pipeline of
:mod:`repro.training` on a pinned reduced-qwen scenario (fixed model /
data seeds, low-vocab prompts so q/k carry retrieval structure), then:

- writes ``experiments/recall/curve.json`` — per-layer/per-head recall
  at every ladder budget, the chosen per-layer budget table, and the
  trained-vs-seed-vs-LSH per-layer metrics;
- writes ``experiments/recall/baseline.json`` — the calibrated
  mean-budget / mean-recall summary in the committed-baseline schema;
- prints the frontier as CSV rows and asserts the two quality
  invariants inline: trained recall >= seed-init recall, and the
  calibrated table's mean recall >= the global-k baseline at a mean
  budget <= the global k.

``--gate`` (the weekly CI step) skips recomputation: it reads the
``baseline.json`` produced by the main run earlier in the job and fails
if its mean recall dropped more than ``--tol`` below the committed
``benchmarks/data/recall_baseline.json``, or if the mean budget rose
above the committed global budget.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import Model
from repro.training import (calibrate_budget_table, recall_vs_budget,
                            train_model_hashes, write_json)
from repro.training.calibrate import _candidate_budgets

COMMITTED = os.path.join(os.path.dirname(__file__), "data",
                         "recall_baseline.json")
OUT_DIR = os.path.join("experiments", "recall")

# the pinned scenario: 4-layer reduced qwen (3 selecting layers) at
# model seed 2 / data seed 2, vocab-8 prompts (low vocab -> structured
# q/k, where trained hashes beat random projections on a random-init
# model), 4 batches of (2, 96) with the last held out
SEED = 2
VOCAB = 8
BATCHES, B, S = 4, 2, 96


def pinned_scenario():
    # config dtype (bfloat16) kept as-is: the committed baseline was
    # calibrated on the bf16 q/k this config actually serves with
    cfg = get_reduced("qwen1.5-0.5b", n_layers=4)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(SEED))
    rng = np.random.default_rng(SEED)
    batches = [{"tokens": rng.integers(0, VOCAB, (B, S))}
               for _ in range(BATCHES)]
    return cfg, model, params, batches


def run(out_dir: str = OUT_DIR):
    cfg, model, params, batches = pinned_scenario()
    params, trained, metrics = train_model_hashes(
        model, params, batches, epochs=8, iters=10,
        n_queries=32, m_keys=32, seed=0)
    table, baseline = calibrate_budget_table(
        model, params, batches[-1], layers=sorted(trained),
        weights=trained)
    global_k = baseline["global_budget"]
    ladder = _candidate_budgets(global_k, S)
    curves = recall_vs_budget(model, params, batches[-1], ladder,
                              layers=sorted(trained), weights=trained)
    write_json(os.path.join(out_dir, "curve.json"), {
        "scenario": {"arch": "qwen1.5-0.5b", "n_layers": cfg.n_layers,
                     "seed": SEED, "vocab": VOCAB, "batch": B,
                     "seq_len": S},
        "curves": {str(l): c for l, c in curves.items()},
        "table": table,
        "baseline": baseline,
        "layers": [dataclasses.asdict(m) for m in metrics],
    })
    write_json(os.path.join(out_dir, "baseline.json"), baseline)

    rec_tr = float(np.mean([m.recall_trained for m in metrics]))
    rec_seed = float(np.mean([m.recall_seed for m in metrics]))
    rec_lsh = float(np.mean([m.recall_lsh for m in metrics]))
    for l, c in sorted(curves.items()):
        for k, r in zip(c["budgets"], c["mean"]):
            print(f"recall_budget_curve/layer{l}_k{k},0,{r:.4f}")
    print(f"recall_budget_curve/recall_trained,0,{rec_tr:.4f}")
    print(f"recall_budget_curve/recall_seed,0,{rec_seed:.4f}")
    print(f"recall_budget_curve/recall_lsh,0,{rec_lsh:.4f}")
    print(f"recall_budget_curve/mean_budget,0,{baseline['mean_budget']}")
    print(f"recall_budget_curve/global_budget,0,{global_k}")
    print(f"recall_budget_curve/mean_recall,0,"
          f"{baseline['mean_recall']:.4f}")
    assert rec_tr >= rec_seed, \
        f"trained hash recall regressed below seed init: " \
        f"{rec_tr:.4f} < {rec_seed:.4f}"
    assert baseline["mean_budget"] <= global_k, \
        "calibrated mean budget exceeds the global budget"
    return baseline


def gate(out_dir: str = OUT_DIR, tol: float = 0.02) -> int:
    """Compare this job's baseline.json against the committed one."""
    cur_path = os.path.join(out_dir, "baseline.json")
    if not os.path.exists(cur_path):
        print(f"recall gate: {cur_path} missing — run "
              f"benchmarks/recall_budget_curve.py first", file=sys.stderr)
        return 1
    with open(cur_path) as f:
        cur = json.load(f)
    with open(COMMITTED) as f:
        ref = json.load(f)
    ok = True
    if cur["mean_recall"] < ref["mean_recall"] - tol:
        print(f"recall gate FAIL: mean recall {cur['mean_recall']:.4f} "
              f"< committed {ref['mean_recall']:.4f} - tol {tol}",
              file=sys.stderr)
        ok = False
    if cur["mean_budget"] > ref["global_budget"]:
        print(f"recall gate FAIL: mean budget {cur['mean_budget']} > "
              f"global {ref['global_budget']}", file=sys.stderr)
        ok = False
    if ok:
        print(f"recall gate OK: recall {cur['mean_recall']:.4f} "
              f"(committed {ref['mean_recall']:.4f}), budget "
              f"{cur['mean_budget']} vs global {ref['global_budget']}")
    return 0 if ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--gate", action="store_true",
                    help="compare a prior run against the committed "
                         "baseline instead of recomputing")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--tol", type=float, default=0.02)
    args = ap.parse_args(argv)
    if args.gate:
        sys.exit(gate(args.out, args.tol))
    return run(args.out)


if __name__ == "__main__":
    main()
