"""Paper Table 3 analogue: HATA-off vs MagicPIG cost model at the
paper's settings (36K/72K prefill, 500 decode steps), plus an exactness
check of the functional offload simulator."""
from __future__ import annotations

from repro.core.offload import (OffloadPlatform, hata_off_decode_time,
                                magicpig_decode_time)


def run():
    plat = OffloadPlatform()
    rows = []
    for name, s, n_layers, h_kv, g in (
            ("llama2-36k", 36_000, 32, 32, 1),
            ("llama3.1-72k", 72_000, 32, 8, 4)):
        budget = max(512, int(0.0156 * s))
        t_h = hata_off_decode_time(s, 128, h_kv, g, budget=budget,
                                   rbit=128, plat=plat) * n_layers * 500
        t_m = magicpig_decode_time(s, 128, h_kv, g,
                                   plat=plat) * n_layers * 500
        rows.append({"model": name, "hata_off_s": t_h,
                     "magicpig_s": t_m, "speedup": t_m / t_h})
    return rows


def main():
    for row in run():
        print(f"offload/{row['model']}/decode_speedup,0,"
              f"{row['speedup']:.2f}")
    return True


if __name__ == "__main__":
    main()
