"""Paper Fig. 9 analogue: the three hardware optimizations' effect, as
HBM-byte deltas on one decode step (plus CPU wall-clock of the fused vs
staged xla graphs where measurable).

GPU (paper)                      TPU (this repo)                 metric
Score op (53.2%)                 XOR+popcount streaming codes    bytes:
                                 vs loading full K rows            codes
FusedAttn (23.8%)                gather fused into flash decode  bytes:
                                 vs materializing gathered K/V     rows
Encode (7.6%)                    fused proj+sign+bitpack vs      bytes:
                                 materializing ±1 intermediate     s*rbit
"""
from __future__ import annotations

import numpy as np


def run(s=131072, d=128, h_kv=8, g=4, budget_frac=0.0156, rbit=128):
    budget = max(512, int(budget_frac * s))
    kv_row = 2 * d * 2
    # stage 0: naive "simple" implementation
    naive_score = s * d * 2                 # full K qk scores
    naive_gather = 2 * budget * kv_row      # gathered copy + re-read
    naive_encode = 2 * (1 * rbit * 1)       # ±1 intermediate (decode: 1 tok)
    attn = budget * kv_row
    total0 = (naive_score + naive_gather + naive_encode + attn) * h_kv
    # + Score: hamming over packed codes instead of qk over K
    score = s * rbit // 8
    total1 = (score + naive_gather + naive_encode + attn) * h_kv
    # + FusedAttn: gather folded into flash decode (no materialized copy)
    total2 = (score + naive_encode + attn) * h_kv
    # + Encode fusion: no ±1 intermediate
    total3 = (score + attn) * h_kv
    stages = [("simple", total0), ("+score", total1),
              ("+fused_attn", total2), ("+encode", total3)]
    out = []
    prev = None
    for name, t in stages:
        cut = 0.0 if prev is None else (prev - t) / total0
        out.append({"stage": name, "bytes": t,
                    "cumulative_speedup": total0 / t,
                    "stage_cut_frac": cut})
        prev = t
    return out


def main():
    for row in run():
        print(f"opt_ablation/{row['stage']},0,"
              f"{row['cumulative_speedup']:.2f}")
    return True


if __name__ == "__main__":
    main()
