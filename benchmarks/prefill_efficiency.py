"""Chunked-prefill efficiency: the paged Pallas flash-prefill kernel vs
the XLA gathered-logical-view path (PR 4's tentpole).

Two views:

  * **Kernel wall-clock** (pallas interpret vs xla, CPU): one layer's
    chunk attention over a paged pool at page_size ∈ {8, 128}. The
    pallas path runs ``flash_prefill_paged`` — pages fetched in place
    through the block-table index_map — where the xla path first
    materializes the (B, S_log, H_kv, d) gathered logical view per
    chunk per layer. Interpret mode measures lowered-graph cost, not
    TPU time; the structural win (zero gather traffic, one compiled
    chunk shape) is what carries to hardware.

  * **Engine tokens/s** (``--paged``, the weekly-CI entry): end-to-end
    chunked prefill throughput of ``PagedServingEngine`` under the
    pallas kernels vs the xla gathered path on the same request mix,
    with a compile-count assertion (one chunk shape serves every chunk
    position — the former static-q_offset kernel recompiled per
    position).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.kernels import ops


def wallclock_chunk_kernel(s_log=1024, chunk=64, h_kv=2, g=4, d=64,
                           page_size=8):
    """One layer's chunk attention: paged pallas kernel vs XLA gather."""
    rng = np.random.default_rng(0)
    h = h_kv * g
    t = s_log // page_size
    n_pages = t + 1
    q = jnp.asarray(rng.standard_normal((1, chunk, h, d)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal(
        (n_pages, page_size, h_kv, d)), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal(
        (n_pages, page_size, h_kv, d)), jnp.float32)
    bt = jnp.arange(1, t + 1, dtype=jnp.int32)[None]
    ctx = jnp.int32(s_log - chunk)

    fn = jax.jit(lambda q_, ctx_: ops.chunk_attention_paged(
        q_, k_pool, v_pool, bt, ctx_))
    with ops.use_impl("pallas"):
        pallas_us = timer(fn, q, ctx)
    fn2 = jax.jit(lambda q_, ctx_: ops.chunk_attention_paged(
        q_, k_pool, v_pool, bt, ctx_))
    with ops.use_impl("xla"):
        xla_us = timer(fn2, q, ctx)
    return {"page": page_size, "pallas_us": pallas_us,
            "xla_us": xla_us, "ratio": xla_us / pallas_us}


def paged_prefill_throughput(n_requests=6, prompt_len=40, new_tokens=4,
                             page_size=8):
    """Engine-level chunked-prefill tokens/s, pallas kernels vs the XLA
    gathered path, identical greedy outputs asserted."""
    import dataclasses as dc
    from repro.configs import get_reduced
    from repro.models import Model
    from repro.serving import PagedServingEngine, Request

    cfg = get_reduced("qwen1.5-0.5b")
    cfg = dc.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
               .astype(np.int32) for _ in range(n_requests)]

    results = {}
    for impl in ("xla", "pallas"):
        reqs = [Request(prompt=p.copy(), max_new_tokens=new_tokens,
                        id=5000 + i) for i, p in enumerate(prompts)]
        with ops.use_impl(impl):
            eng = PagedServingEngine(model, params, num_pages=64,
                                     page_size=page_size, max_batch=4,
                                     prefill_chunk=2 * page_size,
                                     prefix_sharing=False)
            t0 = time.perf_counter()
            done = eng.run(reqs)
            dt = time.perf_counter() - t0
        assert eng._chunk._cache_size() == 1, \
            "chunked prefill recompiled across chunk positions"
        results[impl] = {
            "tok_s": n_requests * (prompt_len + new_tokens) / dt,
            "outputs": {r.id: r.output for r in done},
            "chunks": eng.stats["prefill_chunks"],
        }
    assert results["xla"]["outputs"] == results["pallas"]["outputs"], \
        "pallas chunked prefill diverged from the xla path"
    return results


def run_paged():
    res = paged_prefill_throughput()
    for impl in ("xla", "pallas"):
        r = res[impl]
        print(f"prefill_serving/{impl}_tok_s,{r['tok_s']:.1f},"
              f"{r['tok_s'] / res['xla']['tok_s']:.2f}")
    print(f"prefill_serving/chunks,0,{res['pallas']['chunks']}")
    return res


def main():
    if "--paged" in sys.argv:
        return run_paged()
    for page in (8, 128):
        row = wallclock_chunk_kernel(page_size=page)
        print(f"prefill_chunk/page{page}/xla_gathered,"
              f"{row['xla_us']:.0f},1.0")
        print(f"prefill_chunk/page{page}/pallas_paged,"
              f"{row['pallas_us']:.0f},{row['ratio']:.2f}")
    return None


if __name__ == "__main__":
    main()
