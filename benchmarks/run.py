# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator — one module per paper artifact:

  recall_accuracy    Tables 1/2 (selection-recall proxy)
  recall_budget_curve hash-subsystem frontier + weekly recall gate
  decode_efficiency  Figs. 4/5 (HBM byte model + CPU wall-clock)
  prefill_efficiency beyond-paper: paged flash-prefill kernel vs gather
  budget_ablation    Fig. 7
  hashbits_ablation  Fig. 8
  opt_ablation       Fig. 9
  offload_model      Table 3
  offload_efficiency beyond-paper: tiered OffloadedView residency curve
  distributed_topk   beyond-paper SP selection quality
  serving_load       beyond-paper serving-plane load test (TTFT/ITL
                     percentiles, async-vs-sync tokens/s)
  autotune_sweep     beyond-paper kernel block-size search
  roofline           §Roofline (reads experiments/dryrun/*.json and
                     the autotune sweep artifacts)
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (autotune_sweep, budget_ablation,
                            decode_efficiency, distributed_topk,
                            hashbits_ablation, offload_efficiency,
                            offload_model, opt_ablation,
                            prefill_efficiency, recall_accuracy,
                            recall_budget_curve, roofline,
                            serving_load)
    suites = [
        ("recall_accuracy", recall_accuracy.main),
        ("recall_budget_curve", recall_budget_curve.main),
        ("decode_efficiency", decode_efficiency.main),
        ("prefill_efficiency", prefill_efficiency.main),
        ("budget_ablation", budget_ablation.main),
        ("hashbits_ablation", hashbits_ablation.main),
        ("opt_ablation", opt_ablation.main),
        ("offload_model", offload_model.main),
        ("offload_efficiency", offload_efficiency.main),
        ("distributed_topk", distributed_topk.main),
        # explicit empty argv: the orchestrator's own argv must not
        # leak into the suite's argparse
        ("serving_load", lambda: serving_load.main([])),
        # before roofline: roofline reads the sweep artifacts
        ("autotune_sweep", autotune_sweep.main),
        ("roofline", roofline.main),
    ]
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()[-2000:]}")
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
