"""Beyond-paper distributed selection quality: exact two-stage top-k vs
the zero-index-traffic local-split approximation (DESIGN.md §4) —
recall of local-split selection vs exact, across shard counts."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def run(s=4096, budget=128, shard_counts=(4, 16, 64), trials=20):
    rng = np.random.default_rng(0)
    out = []
    for p in shard_counts:
        recalls = []
        for _ in range(trials):
            scores = jnp.asarray(rng.standard_normal(s), jnp.float32)
            _, exact = jax.lax.top_k(scores, budget)
            exact = set(np.asarray(exact).tolist())
            per = budget // p
            local = scores.reshape(p, s // p)
            _, li = jax.lax.top_k(local, max(per, 1))
            gi = (li + (jnp.arange(p) * (s // p))[:, None]).reshape(-1)
            got = set(np.asarray(gi).tolist())
            recalls.append(len(got & exact) / budget)
        out.append({"shards": p, "recall": float(np.mean(recalls))})
    return out


def main():
    for row in run():
        print(f"distributed_topk/local_split_recall/p{row['shards']},0,"
              f"{row['recall']:.4f}")
    return True


if __name__ == "__main__":
    main()
