"""Shared benchmark substrate: a tiny LM trained on the induction task
(so attention develops real retrieval structure), hash-trained weights,
and harvested q/k — reused by every accuracy benchmark."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import hashing
from repro.data.hash_dataset import build_triplets_per_head, harvest_qk
from repro.data.synthetic import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim.adamw import adamw_init


@functools.lru_cache(maxsize=1)
def tiny_lm(steps: int = 120):
    cfg = get_reduced("qwen1.5-0.5b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, base_lr=1e-3,
                                   total_steps=steps),
                   donate_argnums=(0, 1))
    opt = adamw_init(params)
    src = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    for i in range(steps):
        params, opt, _ = step(params, opt,
                              {"tokens": jnp.asarray(src.batch_at(i))})
    return cfg, model, params


@functools.lru_cache(maxsize=4)
def harvested_layer(layer: int = -1, seq_len: int = 96):
    cfg, model, params = tiny_lm()
    layer = layer % cfg.n_layers
    src = SyntheticLM(cfg.vocab_size, seq_len, 1, seed=7)
    batches = tuple({"tokens": jnp.asarray(src.batch_at(i))}
                    for i in range(3))
    return cfg, model, params, layer, batches


def trained_hash(layer: int, rbit: int):
    cfg, model, params, layer, batches = harvested_layer(layer)
    hcfg = dataclasses.replace(cfg.hata, rbit=rbit)
    q, k, s = build_triplets_per_head(model, params, list(batches[:2]),
                                      layer, hcfg, n_queries=48,
                                      m_keys=48)
    w = hashing.train_hash_weights_per_head(
        jax.random.PRNGKey(0), jnp.asarray(q), jnp.asarray(k),
        jnp.asarray(s), rbit=rbit, hcfg=hcfg)
    qh, kh = harvest_qk(model, params, batches[2], layer)
    return w, np.asarray(qh), np.asarray(kh)


def timer(fn, *args, reps: int = 5) -> float:
    fn(*args)                                  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us
