"""Paper Fig. 8: selection recall vs hash bit count (32 -> 256)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import harvested_layer, trained_hash
from repro.core import baselines, topk
from repro.kernels import ops


def run(rbits=(32, 64, 128), budget_frac: float = 0.1):
    cfg, model, params, layer, batches = harvested_layer(-1)
    out = []
    for rbit in rbits:
        w, qh, kh = trained_hash(-1, rbit)
        b, s, h, d = qh.shape
        h_kv = kh.shape[2]
        g = h // h_kv
        budget = max(2, int(budget_frac * s))
        recs = []
        for hi in range(h_kv):
            keys = jnp.asarray(kh[0, :, hi])
            qs = jnp.asarray(qh[0, s // 2:, hi * g:(hi + 1) * g])
            true = jax.vmap(
                lambda qq: baselines.exact_scores(qq, keys))(qs)
            kc = ops.hash_encode(keys, w[hi])
            est = jax.vmap(lambda qq: baselines.lsh_scores(
                qq, kc, w[hi], rbit).astype(jnp.float32))(qs)
            recs.append(float(topk.selection_recall(est, true,
                                                    budget).mean()))
        out.append({"rbit": rbit, "recall": float(np.mean(recs))})
    return out


def main():
    for row in run():
        print(f"hashbits_ablation/rbit{row['rbit']},0,"
              f"{row['recall']:.4f}")
    return True


if __name__ == "__main__":
    main()
