"""§Roofline deliverable: turn the dry-run JSONs into the per-(arch x
shape x mesh) roofline table — three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful-compute ratio, and per-device
memory — plus the per-kernel achieved-vs-peak HBM bandwidth table from
the autotune sweep artifacts (``benchmarks/autotune_sweep.py``), so
block-size tuning chases a roofline fraction, not a raw wallclock.
Writes experiments/roofline.md and prints CSV."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config, get_shape
from repro.launch.analytic import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   model_bytes, model_flops,
                                   roofline_terms)


def load_records(dirname: str = "experiments/dryrun") -> List[Dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        rec["file"] = os.path.basename(fn)
        out.append(rec)
    return out


def analyze_record(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return {"arch": rec.get("arch"), "shape": rec.get("shape"),
                "mesh": rec.get("mesh"), "ok": False,
                "error": rec.get("error", "?")[:120]}
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    chips = rec["chips"]
    hc = rec["hlo_cost"]
    terms = roofline_terms(hc["flops"], hc["bytes"],
                           hc["collective_bytes"])
    mf = model_flops(cfg, shape)
    mb = model_bytes(cfg, shape, hata=rec.get("hata", True))
    analytic = roofline_terms(mf["model_flops"] / chips, mb / chips,
                              hc["collective_bytes"])
    mem = rec.get("memory", {})
    hbm_gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0)) / 2 ** 30
    useful = (mf["model_flops"] / chips) / max(hc["flops"], 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "hata": rec.get("hata", True), "ok": True,
        "chips": chips,
        "hlo_flops_dev": hc["flops"], "hlo_bytes_dev": hc["bytes"],
        "coll_bytes_dev": hc["collective_bytes"],
        "collectives": hc.get("collectives", {}),
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "bottleneck": terms["bottleneck"],
        "bound_s": terms["bound_s"],
        "analytic_bound_s": analytic["bound_s"],
        "analytic_bottleneck": analytic["bottleneck"],
        "useful_flops_ratio": useful,
        "roofline_frac": analytic["bound_s"] / max(terms["bound_s"],
                                                   1e-12),
        "hbm_gib_dev": hbm_gib,
        "fits_16g": hbm_gib <= 16.0,
        "compile_s": rec.get("compile_s"),
    }


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | hata | compute_s | memory_s | "
           "coll_s | bottleneck | useful | HBM GiB/dev | fits 16G |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| - | FAILED: {r['error']} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {'on' if r['hata'] else 'off'} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['hbm_gib_dev']:.1f} "
            f"| {'Y' if r['fits_16g'] else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def load_kernel_sweeps(dirname: str = "experiments/autotune"
                       ) -> List[Dict]:
    """Per-kernel measurement rows from the autotune sweep artifacts
    (one ``sweep_<backend>.json`` per backend that ran
    ``benchmarks/autotune_sweep.py``)."""
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirname, "sweep_*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        for r in rec.get("results", []):
            rows.append({**r, "interpret": rec.get("interpret", True)})
    return rows


def kernel_bandwidth_rows(sweeps: List[Dict]) -> List[Dict]:
    """Achieved-vs-peak HBM bandwidth per kernel: bytes one call must
    move / best bit-exact wallclock, over the v5e HBM peak. Interpret
    measurements price the grid walk, not the memory system — the
    roofline fraction is only meaningful for compiled backends, but
    the *bytes* column and the tuned-vs-default ratio are backend-free.
    """
    out = []
    for r in sweeps:
        best_gbps = r["bytes_moved"] / (r["best_us"] * 1e-6) / 1e9
        base_gbps = (r["bytes_moved"] / (r["baseline_us"] * 1e-6)
                     / 1e9)
        out.append({
            "kernel": r["kernel"], "backend": r["backend"],
            "interpret": r["interpret"],
            "bytes_moved": r["bytes_moved"],
            "baseline_us": r["baseline_us"], "best_us": r["best_us"],
            "speedup": r["speedup"],
            "achieved_gbps": best_gbps,
            "baseline_gbps": base_gbps,
            "peak_gbps": HBM_BW / 1e9,
            "peak_frac": best_gbps / (HBM_BW / 1e9),
        })
    return out


def kernel_bandwidth_markdown(rows: List[Dict]) -> str:
    hdr = ("| kernel | backend | MB/call | default µs | tuned µs | "
           "speedup | achieved GB/s | % of peak |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        note = " (interp)" if r["interpret"] else ""
        lines.append(
            f"| {r['kernel']} | {r['backend']}{note} "
            f"| {r['bytes_moved'] / 2**20:.1f} "
            f"| {r['baseline_us']:.0f} | {r['best_us']:.0f} "
            f"| {r['speedup']:.2f}x | {r['achieved_gbps']:.2f} "
            f"| {100 * r['peak_frac']:.2f}% |")
    return hdr + "\n".join(lines) + "\n"


def main(dirname: str = "experiments/dryrun",
         out_md: str = "experiments/roofline.md",
         autotune_dir: str = "experiments/autotune"):
    recs = load_records(dirname)
    rows = [analyze_record(r) for r in recs]
    rows = [r for r in rows if r]
    bw_rows = kernel_bandwidth_rows(load_kernel_sweeps(autotune_dir))
    if out_md:
        os.makedirs(os.path.dirname(out_md), exist_ok=True)
        with open(out_md, "w") as f:
            f.write("# Roofline table (from multi-pod dry-run)\n\n"
                    "Terms are per-device seconds on v5e constants "
                    f"({PEAK_FLOPS/1e12:.0f} TFLOP/s, "
                    f"{HBM_BW/1e9:.0f} GB/s HBM, "
                    f"{ICI_BW/1e9:.0f} GB/s ICI). 'useful' = analytic "
                    "MODEL_FLOPS / parsed HLO FLOPs per device.\n\n")
            f.write(to_markdown(rows))
            f.write("\n## Per-kernel achieved vs peak HBM bandwidth\n\n"
                    "From the autotune sweep's best *bit-exact* config "
                    "per kernel (benchmarks/autotune_sweep.py). "
                    "Interpret-mode rows price the grid walk, not the "
                    "memory system — their %-of-peak is a lower bound "
                    "placeholder until a compiled backend writes its "
                    "sweep artifact.\n\n")
            if bw_rows:
                f.write(kernel_bandwidth_markdown(bw_rows))
            else:
                f.write("(no sweep artifacts under "
                        f"{autotune_dir}/ — run "
                        "`python -m benchmarks.autotune_sweep`)\n")
    n_fail = sum(1 for r in rows if not r.get("ok"))
    for r in rows:
        if r.get("ok"):
            print(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
                  f"{'' if r['hata'] else '_dense'},0,"
                  f"{r['bound_s']:.3e}")
    for r in bw_rows:
        print(f"roofline/kernel_bw/{r['kernel']}_{r['backend']},"
              f"{r['best_us']:.1f},{r['peak_frac']:.4f}")
    print(f"roofline/cells,{len(rows)},{n_fail} failed")
    return rows


if __name__ == "__main__":
    main()
