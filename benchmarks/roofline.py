"""§Roofline deliverable: turn the dry-run JSONs into the per-(arch x
shape x mesh) roofline table — three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful-compute ratio, and per-device
memory. Writes experiments/roofline.md and prints CSV."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config, get_shape
from repro.launch.analytic import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   model_bytes, model_flops,
                                   roofline_terms)


def load_records(dirname: str = "experiments/dryrun") -> List[Dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        rec["file"] = os.path.basename(fn)
        out.append(rec)
    return out


def analyze_record(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok"):
        return {"arch": rec.get("arch"), "shape": rec.get("shape"),
                "mesh": rec.get("mesh"), "ok": False,
                "error": rec.get("error", "?")[:120]}
    cfg = get_config(rec["arch"])
    shape = get_shape(rec["shape"])
    chips = rec["chips"]
    hc = rec["hlo_cost"]
    terms = roofline_terms(hc["flops"], hc["bytes"],
                           hc["collective_bytes"])
    mf = model_flops(cfg, shape)
    mb = model_bytes(cfg, shape, hata=rec.get("hata", True))
    analytic = roofline_terms(mf["model_flops"] / chips, mb / chips,
                              hc["collective_bytes"])
    mem = rec.get("memory", {})
    hbm_gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0)) / 2 ** 30
    useful = (mf["model_flops"] / chips) / max(hc["flops"], 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "hata": rec.get("hata", True), "ok": True,
        "chips": chips,
        "hlo_flops_dev": hc["flops"], "hlo_bytes_dev": hc["bytes"],
        "coll_bytes_dev": hc["collective_bytes"],
        "collectives": hc.get("collectives", {}),
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "bottleneck": terms["bottleneck"],
        "bound_s": terms["bound_s"],
        "analytic_bound_s": analytic["bound_s"],
        "analytic_bottleneck": analytic["bottleneck"],
        "useful_flops_ratio": useful,
        "roofline_frac": analytic["bound_s"] / max(terms["bound_s"],
                                                   1e-12),
        "hbm_gib_dev": hbm_gib,
        "fits_16g": hbm_gib <= 16.0,
        "compile_s": rec.get("compile_s"),
    }


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | hata | compute_s | memory_s | "
           "coll_s | bottleneck | useful | HBM GiB/dev | fits 16G |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| - | FAILED: {r['error']} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {'on' if r['hata'] else 'off'} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['hbm_gib_dev']:.1f} "
            f"| {'Y' if r['fits_16g'] else 'N'} |")
    return hdr + "\n".join(lines) + "\n"


def main(dirname: str = "experiments/dryrun",
         out_md: str = "experiments/roofline.md"):
    recs = load_records(dirname)
    rows = [analyze_record(r) for r in recs]
    rows = [r for r in rows if r]
    if out_md:
        os.makedirs(os.path.dirname(out_md), exist_ok=True)
        with open(out_md, "w") as f:
            f.write("# Roofline table (from multi-pod dry-run)\n\n"
                    "Terms are per-device seconds on v5e constants "
                    f"({PEAK_FLOPS/1e12:.0f} TFLOP/s, "
                    f"{HBM_BW/1e9:.0f} GB/s HBM, "
                    f"{ICI_BW/1e9:.0f} GB/s ICI). 'useful' = analytic "
                    "MODEL_FLOPS / parsed HLO FLOPs per device.\n\n")
            f.write(to_markdown(rows))
    n_fail = sum(1 for r in rows if not r.get("ok"))
    for r in rows:
        if r.get("ok"):
            print(f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}"
                  f"{'' if r['hata'] else '_dense'},0,"
                  f"{r['bound_s']:.3e}")
    print(f"roofline/cells,{len(rows)},{n_fail} failed")
    return rows


if __name__ == "__main__":
    main()
