"""Kernel block-size autotune sweep (`repro.kernels.autotune` front end).

Runs the measured per-kernel search, prints the
``name,us_per_call,derived`` CSV the harness expects (derived =
speedup of the best bit-exact candidate over the builtin default), and
writes two artifacts under ``experiments/autotune/``:

  * ``sweep_<backend>.json`` — the full per-candidate report
    (wallclock, bit-exactness verdict, maxdiff, bytes moved) that
    ``benchmarks/roofline.py`` turns into the per-kernel
    achieved-vs-peak HBM bandwidth table;
  * ``table_<backend>.json`` — a ready-to-use tuning table of the
    winners (only entries beating the default past the jitter guard),
    loadable via ``REPRO_TUNING_TABLE`` or merged into
    ``src/repro/kernels/tuning/default.json``.

Off-TPU this measures interpret mode — wallclock prices the grid walk,
not the memory system, which still ranks row-partition tilings and
exercises the whole search/emit/validate path; the table schema
carries the backend key, so TPU-measured entries slot in unchanged.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List

import jax


def main(out_dir: str = "experiments/autotune",
         reps: int = 3) -> List:
    from repro.kernels import autotune, runtime

    results = autotune.search_all(reps=reps)
    backend = jax.default_backend()
    os.makedirs(out_dir, exist_ok=True)

    report = []
    n_better = 0
    for r in results:
        best = r.best
        speedup = r.baseline_us / best.us
        if best.config != r.baseline and speedup > 1.0:
            n_better += 1
        report.append({
            "kernel": r.kernel, "backend": r.backend, "dtype": r.dtype,
            "size": r.size, "bytes_moved": r.bytes_moved,
            "baseline": r.baseline, "baseline_us": r.baseline_us,
            "best": best.config, "best_us": best.us,
            "speedup": speedup,
            "rejected": len(r.rejected),
            "candidates": [dataclasses.asdict(c) for c in r.candidates],
            "achieved_gbps": r.gbps(best.us),
        })
        print(f"autotune/{r.kernel},{best.us:.1f},{speedup:.3f}")
        print(f"autotune/{r.kernel}_rejected,{len(r.rejected)},"
              f"{len(r.candidates)}")

    with open(os.path.join(out_dir, f"sweep_{backend}.json"), "w") as f:
        json.dump({"backend": backend, "interpret":
                   runtime.use_interpret(), "results": report}, f,
                  indent=1)

    table = autotune.emit_table(results)
    with open(os.path.join(out_dir, f"table_{backend}.json"), "w") as f:
        json.dump(table, f, indent=1)

    # the searched table must actually win somewhere; two kernels is
    # the bar the interpret-mode search is expected to clear via the
    # row-partition (numerics-invariant) axes
    print(f"autotune/kernels_improved,{n_better},"
          f"{len(table['entries'])}")
    assert n_better >= 2, (
        f"searched table beats defaults on only {n_better} kernels")
    return report


if __name__ == "__main__":
    main()
