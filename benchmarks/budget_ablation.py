"""Paper Fig. 7: selection recall vs token budget (HATA vs Loki/Quest).
HATA's recall should degrade most gracefully as the budget shrinks."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import harvested_layer, trained_hash
from repro.core import baselines, topk


def run(fracs=(0.025, 0.05, 0.1, 0.2), rbit: int = 64):
    cfg, model, params, layer, batches = harvested_layer(-1)
    w, qh, kh = trained_hash(-1, rbit)
    b, s, h, d = qh.shape
    h_kv = kh.shape[2]
    g = h // h_kv
    from repro.kernels import ops
    out = []
    for frac in fracs:
        budget = max(2, int(frac * s))
        accs = {"hata": [], "loki": [], "quest": []}
        for hi in range(h_kv):
            keys = jnp.asarray(kh[0, :, hi])
            qs = jnp.asarray(qh[0, s // 2:, hi * g:(hi + 1) * g])
            true = jax.vmap(
                lambda qq: baselines.exact_scores(qq, keys))(qs)
            kc = ops.hash_encode(keys, w[hi])
            est_h = jax.vmap(lambda qq: baselines.lsh_scores(
                qq, kc, w[hi], rbit).astype(jnp.float32))(qs)
            loki = baselines.loki_fit(keys, r=max(4, d // 4))
            est_l = jax.vmap(lambda qq: baselines.loki_scores(
                qq, loki, r=max(4, d // 4)))(qs)
            quest = baselines.quest_fit(keys, block=8)
            est_q = jax.vmap(lambda qq: baselines.quest_scores(
                qq, quest, block=8, s=s))(qs)
            for name, est in (("hata", est_h), ("loki", est_l),
                              ("quest", est_q)):
                accs[name].append(float(topk.selection_recall(
                    est, true, budget).mean()))
        out.append({"frac": frac,
                    **{k: float(np.mean(v)) for k, v in accs.items()}})
    return out


def main():
    for row in run():
        for m in ("hata", "loki", "quest"):
            print(f"budget_ablation/frac{row['frac']}/{m},0,"
                  f"{row[m]:.4f}")
    return True


if __name__ == "__main__":
    main()
