"""Paper Fig. 4/5 analogue: decode-step cost across methods, sequence
lengths and batch sizes.

Five views:
  * HBM byte model (first principles, v5e constants): on the
    memory-bound decode roofline, speedup == byte ratio — this is the
    at-scale prediction.
  * CPU wall-clock of one attention layer's decode (xla path): sanity
    check that the implemented ops realize the predicted ordering.
  * Batched-pipeline wall-clock (pallas interpret): the new single-
    dispatch score->select->gather pipeline vs the legacy per-(B, H_kv)
    vmapped kernels, at the same shapes. Interpret mode measures the
    lowered-graph cost on CPU, not TPU time; the structural win (no
    transposed cache copies, no per-head dispatch, no exact-recompute
    correction) is what carries to hardware.
  * MLA-pipeline wall-clock (pallas interpret): the batched latent
    pipeline (flattened q encode, batched latent Hamming kernel,
    two-stage top-k, split-latent paged gather) vs the inline-jnp path
    it replaced (per-lane vmapped q encode, materialized (B, H, S, W)
    popcount tensor, flat lax.top_k, XLA row gathers + concatenated
    softmax), at the acceptance shape B=4, S=4096.
  * SP-mode ladder wall-clock (subprocess, 8 host devices): one decode
    attention wave under naive / two_stage / local_split with the
    sequence-sharded cache — records the §Perf hillclimb ladder.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.configs.base import HataConfig
from repro.core import baselines, kvcache
from repro.core.hash_attention import (hata_decode, hata_decode_batched,
                                       mask_scores)
from repro.core.topk import chunked_topk
from repro.kernels import ops
from repro.launch.analytic import HBM_BW


def byte_model(seqs=(32768, 131072, 262144), budget_frac=0.0156,
               d=128, rbit=128):
    rows = []
    for s in seqs:
        budget = max(512, int(budget_frac * s))
        row = {"seq": s}
        for m in ("dense", "exact-topk", "loki", "quest", "hata",
                  "lsh"):
            by = baselines.decode_bytes_per_kv_head(
                m, s, d, budget=budget, rbit=rbit)
            row[m] = by
            row[m + "_us@v5e"] = by / HBM_BW * 1e6
        row["speedup_vs_dense"] = row["dense"] / row["hata"]
        rows.append(row)
    return rows


def wallclock_layer(s=4096, b=4, h=8, h_kv=2, d=64, rbit=64,
                    budget=128):
    """One layer's decode on CPU: dense vs HATA (xla ops path)."""
    rng = np.random.default_rng(0)
    hcfg = HataConfig(rbit=rbit, budget_min=budget, budget_max=budget,
                      budget_frac=budget / s)
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=rbit,
                                  dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2**32, cache.codes.shape,
                                       dtype=np.uint32)))
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)),
                    jnp.float32)
    pos = jnp.int32(s - 2)

    dense = jax.jit(lambda qq: ops.decode_attention(
        qq, cache.k, cache.v, jnp.int32(s - 1)))
    hata = jax.jit(lambda qq: hata_decode(
        qq, k1, v1, w, cache, hcfg=hcfg, pos=pos).out)
    t_dense = timer(dense, q)
    t_hata = timer(hata, q)
    return {"dense_us": t_dense, "hata_us": t_hata,
            "speedup": t_dense / t_hata}


def _legacy_vmapped_decode(q, k1, v1, w, cache, hcfg, pos):
    """The seed's decode data path: per-(B, H_kv) vmapped Hamming kernel,
    per-head vmapped fused gather with clamped indices, plus the exact
    XLA recomputation that the old correction branch always paid."""
    import jax.numpy as jnp
    from repro.core import hash_attention as ha
    rbit = w.shape[-1]
    s_max = cache.max_len
    cache2 = kvcache.append_kv(cache, k1, v1,
                               ops.hash_encode_heads(k1, w), pos)
    q_codes = ha.aggregate_q_codes(q, w, cache.k.shape[2])
    scores = ops.hamming_scores_vmapped(q_codes, cache2.codes, rbit=rbit)
    scores = ha.mask_scores(scores, pos + 1)
    budget = ha.clamped_budget(hcfg, s_max)
    top_scores, idx = jax.lax.top_k(scores, budget)
    sel_valid = top_scores >= 0
    idx_c = jnp.where(sel_valid, idx, 0)
    out = ops.gather_decode_attention_vmapped(q, cache2.k, cache2.v,
                                              idx_c)
    out_exact = ops.gather_decode_attention(q, cache2.k, cache2.v, idx,
                                            sel_valid=sel_valid,
                                            fused=False)
    return jnp.where(jnp.any(~sel_valid), out_exact, out)


def wallclock_batched_pipeline(s=4096, b=4, h=8, h_kv=2, d=64, rbit=64,
                               budget=64):
    """Batched fused pipeline vs the seed's vmapped path, pallas
    interpret mode (acceptance shape: B=4, S=4096)."""
    rng = np.random.default_rng(0)
    hcfg = HataConfig(rbit=rbit, budget_min=budget, budget_max=budget,
                      budget_frac=budget / s)
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=rbit,
                                  dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2**32, cache.codes.shape,
                                       dtype=np.uint32)))
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)), jnp.float32)
    # ragged depths: slots at different fill levels, as the engine sees
    pos = jnp.asarray(rng.integers(s // 2, s - 1, b), jnp.int32)

    with ops.use_impl("pallas"):
        batched = jax.jit(lambda qq: hata_decode_batched(
            qq, k1, v1, w, cache, hcfg=hcfg, pos=pos,
            fused_gather=True).out)
        legacy = jax.jit(lambda qq: _legacy_vmapped_decode(
            qq, k1, v1, w, cache, hcfg, pos))
        t_batched = timer(batched, q)
        t_legacy = timer(legacy, q)
    return {"batched_us": t_batched, "vmapped_us": t_legacy,
            "speedup": t_legacy / t_batched}


def _interleaved_medians(fn_a, fn_b, *args, reps: int = 25):
    """Median-of-reps wall clock (us) for two functions with the reps
    interleaved A/B/A/B — the MLA rows compare two ~3 ms pipelines, so
    a mean is hostage to scheduler spikes and back-to-back measurement
    windows are hostage to load drift between them."""
    import time
    fn_a(*args)                                     # compile
    fn_b(*args)
    ta, tb = [], []
    for _ in range(reps):
        for fn, ts in ((fn_a, ta), (fn_b, tb)):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
    return float(np.median(ta) * 1e6), float(np.median(tb) * 1e6)


def _inline_mla_decode(q_lat, w, ckv, krope, codes, n_valid, budget, *,
                       scale):
    """The pre-refactor MLA HATA decode, kept verbatim: per-lane vmapped
    q encode, materialized (B, H, S, W) popcount tensor, flat
    lax.top_k, XLA row gathers and a concatenated-latent softmax."""
    import importlib
    _he = importlib.import_module("repro.kernels.hash_encode")
    b, h, _ = q_lat.shape
    s = ckv.shape[1]
    rbit = w.shape[-1]
    enc = jax.vmap(_he.hash_encode, in_axes=(0, None))
    q_codes = enc(q_lat, w[0])                       # (B, H, W)
    x_ = jax.lax.population_count(jnp.bitwise_xor(
        q_codes[:, :, None, :], codes[:, None, :, :]))
    scores = h * rbit - jnp.sum(x_.astype(jnp.int32), axis=(1, 3))
    scores = jnp.where(jnp.arange(s)[None] < n_valid, scores, -1)
    top_scores, idx = jax.lax.top_k(scores, budget)
    ckv_rows = jnp.take_along_axis(ckv, idx[..., None], axis=1)
    kr_rows = jnp.take_along_axis(krope, idx[..., None], axis=1)
    kv = jnp.concatenate([ckv_rows, kr_rows], axis=-1)
    logits = jnp.einsum("bhr,bkr->bhk", q_lat, kv,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where((top_scores >= 0)[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bkr->bhr", probs, ckv_rows,
                      preferred_element_type=jnp.float32)


def wallclock_mla_pipeline(s=4096, b=4, h=16, r=128, rd=32, rbit=128,
                           budget=64):
    """Batched MLA latent pipeline vs the inline-jnp path, pallas
    interpret mode (acceptance shape: B=4, S=4096)."""
    rng = np.random.default_rng(0)
    scale = (r + rd) ** -0.5
    w = jnp.asarray(rng.standard_normal((1, r + rd, rbit)),
                    jnp.float32) / np.sqrt(r + rd)
    ckv = jnp.asarray(rng.standard_normal((b, s, r)), jnp.float32)
    krope = jnp.asarray(rng.standard_normal((b, s, rd)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 2 ** 32, (b, s, rbit // 32),
                                     dtype=np.uint32))
    q_lat = jnp.asarray(rng.standard_normal((b, h, r + rd)), jnp.float32)
    n_valid = jnp.int32(s - 1)

    def batched(q):
        q_codes = ops.hash_encode(q, w[0])           # one flat dispatch
        scores = ops.hamming_scores_latent(q_codes, codes, rbit=rbit)
        scores = mask_scores(scores[:, None], n_valid)[:, 0]
        top_scores, idx = chunked_topk(scores, budget)
        return ops.mla_gather_decode(
            q, ckv, krope, idx, lora_rank=r, scale=scale,
            n_valid=jnp.sum((top_scores >= 0).astype(jnp.int32), -1))

    with ops.use_impl("pallas"):
        jb = jax.jit(batched)
        ji = jax.jit(lambda q: _inline_mla_decode(
            q, w, ckv, krope, codes, n_valid, budget, scale=scale))
        t_inline, t_batched = _interleaved_medians(ji, jb, q_lat)
    return {"batched_us": t_batched, "inline_us": t_inline,
            "speedup": t_inline / t_batched}


_SP_MODES_CODE = """
import dataclasses, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced
from repro.core import hash_attention as ha
from repro.core.kvcache import LayerKVCache
from repro.distributed.decode import SPDecode
from repro.launch.mesh import make_mesh

b, s, budget = {b}, {s}, {budget}
cfg = get_reduced("llama3-405b", d_model=64)
cfg = dataclasses.replace(cfg, dtype="float32", hata=dataclasses.replace(
    cfg.hata, budget_min=budget, budget_max=budget))
h, h_kv, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
rbit = cfg.hata.rbit
mesh = make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
shard = NamedSharding(mesh, P(None, "model", None, None))
kc = jax.device_put(jnp.asarray(
    rng.standard_normal((b, s, h_kv, d)), jnp.float32), shard)
vc = jax.device_put(jnp.asarray(
    rng.standard_normal((b, s, h_kv, d)), jnp.float32), shard)
codes = jax.device_put(jnp.asarray(
    rng.integers(0, 2**32, (b, s, h_kv, rbit // 32), dtype=np.uint32)),
    shard)
cache = LayerKVCache(k=kc, v=vc, codes=codes)
q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)), jnp.float32)
n_valid = jnp.int32(s - 1)

def naive(qq):
    budget_c = ha.clamped_budget(cfg.hata, s, None)
    top, idx, _ = ha.hata_score_select(
        qq, w, cache.codes, rbit=rbit, budget=budget_c, n_valid=n_valid)
    return ha.hata_attend(qq, cache, idx, top >= 0)

def timeit(fn, *args, reps=10):
    fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)

t_naive = timeit(jax.jit(naive), q)
print("decode_sp/naive,{{t:.0f}},1.00".format(t=t_naive))
for mode in ("two_stage", "local_split"):
    strat = SPDecode(mesh, seq_axes=("model",), mode=mode)
    fn = jax.jit(lambda qq: strat.gqa(cfg, qq, w, cache, n_valid, True))
    t = timeit(fn, q)
    print("decode_sp/{{mode}},{{t:.0f}},{{sp:.2f}}".format(
        mode=mode, t=t, sp=t_naive / t))

# ---- paged rows: ShardedView-over-pages (PR-5 paged SP decode) -------
from repro.core import cache_view as cv
from repro.core.paged_cache import PagedKVPool

n_sh, page = 8, 8
t_loc = s // (n_sh * page)
p_loc = b * t_loc
def to_pool(arr):
    # shard i's local page (bi * t_loc + j) holds contiguous rows
    # [i*s_loc + j*page, ...): (B, S, ...) -> (n_sh*p_loc, page, ...)
    a = np.asarray(arr).reshape(b, n_sh, t_loc, page, *arr.shape[2:])
    return jnp.asarray(np.moveaxis(a, 1, 0).reshape(
        n_sh * p_loc, page, *arr.shape[2:]))
cols = np.arange(n_sh * t_loc)
bt_np = (np.arange(b)[:, None] * t_loc
         + (cols % t_loc)[None]).astype(np.int32)
pool_sh = NamedSharding(mesh, P("model", None, None, None))
pview = cv.PagedView(
    PagedKVPool(k=jax.device_put(to_pool(kc), pool_sh),
                v=jax.device_put(to_pool(vc), pool_sh),
                codes=jax.device_put(to_pool(codes), pool_sh)),
    jax.device_put(jnp.asarray(bt_np),
                   NamedSharding(mesh, P(None, "model"))))
for mode in ("two_stage", "local_split"):
    strat = SPDecode(mesh, seq_axes=("model",), mode=mode)
    fn = jax.jit(lambda qq: strat.gqa(cfg, qq, w, pview, n_valid, True))
    t = timeit(fn, q)
    print("decode_sp/{{mode}}_paged,{{t:.0f}},{{sp:.2f}}".format(
        mode=mode, t=t, sp=t_naive / t))
"""


def wallclock_sp_modes(s=16384, b=4, budget=256):
    """SP decode-mode ladder on 8 host devices (subprocess — device
    count locks at jax init). Prints the rows itself; returns True on
    success. Host-device shard_map can't show the ICI byte win, but at
    S >= 16k the structural ordering already appears: naive re-gathers
    the full score vector and rows, two_stage ships only candidate
    pairs, local_split only the (m, l, o) stats."""
    code = _SP_MODES_CODE.format(b=b, s=s, budget=budget)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    if res.returncode != 0:
        print(f"# sp_modes FAILED: {res.stderr[-1500:]}")
        return False
    print(res.stdout, end="")
    return True


def paged_serving(n_requests=8, prefix_len=24, suffix_len=8, new_tokens=8,
                  max_batch=4, page_size=8):
    """Paged-vs-dense serving on a shared-prefix request mix.

    The workload every prefix cache is built for: ``n_requests`` prompts
    share a ``prefix_len`` prefix and differ in a short suffix. Both
    engines must produce identical greedy outputs (asserted); the
    comparison is resource + latency shape:

      * rows — dense reserves max_batch * max_len cache rows per layer
        up front; paged peaks at peak_pages * page_size (live tokens
        plus shared-prefix dedup);
      * TTFT — dense prefills each prompt monolithically inside the
        admission loop; paged interleaves page-sized prefill chunks
        with decode waves;
      * tokens/s — end-to-end wall clock over emitted tokens.
    """
    import dataclasses as dc
    import time
    from repro.configs import get_reduced
    from repro.models import Model
    from repro.serving import PagedServingEngine, Request, ServingEngine

    cfg = get_reduced("qwen1.5-0.5b")
    cfg = dc.replace(cfg, dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(
        0, cfg.vocab_size, suffix_len).astype(np.int32)])
        for _ in range(n_requests)]
    max_len = prefix_len + suffix_len + new_tokens + 8
    reqs = lambda: [Request(prompt=p.copy(), max_new_tokens=new_tokens)
                    for p in prompts]

    dense = ServingEngine(model, params, max_batch=max_batch,
                          max_len=max_len)
    t0 = time.perf_counter()
    done_d = dense.run(reqs())
    t_dense = time.perf_counter() - t0

    # pool sized to the same row budget; the prefix sharing + paging
    # means far fewer pages are ever live
    num_pages = max_batch * (max_len // page_size)
    # max_len_pages matches the dense engine's per-request capacity, so
    # the static HATA budget (a function of logical capacity) is equal
    # on both sides — required for the output-parity assertion below
    eng = PagedServingEngine(model, params, num_pages=num_pages,
                             page_size=page_size, max_batch=max_batch,
                             max_len_pages=max_len // page_size,
                             prefill_chunk=2 * page_size)
    t0 = time.perf_counter()
    done_p = eng.run(reqs())
    t_paged = time.perf_counter() - t0

    by_id_d = {r.prompt.tobytes(): r.output for r in done_d}
    for r in done_p:
        assert r.output == by_id_d[r.prompt.tobytes()], \
            "paged outputs diverged from dense"

    def ttft(rs):
        return float(np.mean([r.t_first_token - r.t_submit for r in rs]))

    toks = n_requests * new_tokens
    return {
        "dense_rows": max_batch * max_len,
        "paged_rows_peak": eng.stats["peak_pages"] * page_size,
        "prefix_hit_tokens": eng.stats["prefix_hit_tokens"],
        "dense_ttft_ms": ttft(done_d) * 1e3,
        "paged_ttft_ms": ttft(done_p) * 1e3,
        "dense_tok_s": toks / t_dense,
        "paged_tok_s": toks / t_paged,
    }


def run_paged():
    ps = paged_serving()
    print(f"paged_serving/dense_rows,0,{ps['dense_rows']}")
    print(f"paged_serving/paged_rows_peak,0,{ps['paged_rows_peak']}")
    print(f"paged_serving/prefix_hit_tokens,0,{ps['prefix_hit_tokens']}")
    print(f"paged_serving/dense_ttft_ms,{ps['dense_ttft_ms']:.1f},1.0")
    print(f"paged_serving/paged_ttft_ms,{ps['paged_ttft_ms']:.1f},"
          f"{ps['dense_ttft_ms'] / ps['paged_ttft_ms']:.2f}")
    print(f"paged_serving/dense_tok_s,{ps['dense_tok_s']:.1f},1.0")
    print(f"paged_serving/paged_tok_s,{ps['paged_tok_s']:.1f},"
          f"{ps['paged_tok_s'] / ps['dense_tok_s']:.2f}")
    return ps


def main():
    if "--paged" in sys.argv:
        run_paged()
        # paged-SP ladder rows (ShardedView-over-pages) ride the weekly
        # --paged job so paged sequence-parallel perf is tracked from
        # day one alongside the contiguous modes
        wallclock_sp_modes()
        return None
    for row in byte_model():
        print(f"decode_bytes/seq{row['seq']}/dense,0,{row['dense']:.0f}")
        print(f"decode_bytes/seq{row['seq']}/hata,0,{row['hata']:.0f}")
        print(f"decode_bytes/seq{row['seq']}/speedup,0,"
              f"{row['speedup_vs_dense']:.2f}")
    wc = wallclock_layer()
    print(f"decode_wallclock/dense,{wc['dense_us']:.0f},1.0")
    print(f"decode_wallclock/hata,{wc['hata_us']:.0f},"
          f"{wc['speedup']:.2f}")
    bp = wallclock_batched_pipeline()
    print(f"decode_pipeline/vmapped,{bp['vmapped_us']:.0f},1.0")
    print(f"decode_pipeline/batched,{bp['batched_us']:.0f},"
          f"{bp['speedup']:.2f}")
    mp = wallclock_mla_pipeline()
    print(f"decode_mla_pipeline/inline,{mp['inline_us']:.0f},1.0")
    print(f"decode_mla_pipeline/batched,{mp['batched_us']:.0f},"
          f"{mp['speedup']:.2f}")
    wallclock_sp_modes()
    return wc


if __name__ == "__main__":
    main()
