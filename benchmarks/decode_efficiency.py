"""Paper Fig. 4/5 analogue: decode-step cost across methods, sequence
lengths and batch sizes.

Three views:
  * HBM byte model (first principles, v5e constants): on the
    memory-bound decode roofline, speedup == byte ratio — this is the
    at-scale prediction.
  * CPU wall-clock of one attention layer's decode (xla path): sanity
    check that the implemented ops realize the predicted ordering.
  * Batched-pipeline wall-clock (pallas interpret): the new single-
    dispatch score->select->gather pipeline vs the legacy per-(B, H_kv)
    vmapped kernels, at the same shapes. Interpret mode measures the
    lowered-graph cost on CPU, not TPU time; the structural win (no
    transposed cache copies, no per-head dispatch, no exact-recompute
    correction) is what carries to hardware.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.configs.base import HataConfig
from repro.core import baselines, kvcache
from repro.core.hash_attention import hata_decode, hata_decode_batched
from repro.kernels import ops
from repro.launch.analytic import HBM_BW


def byte_model(seqs=(32768, 131072, 262144), budget_frac=0.0156,
               d=128, rbit=128):
    rows = []
    for s in seqs:
        budget = max(512, int(budget_frac * s))
        row = {"seq": s}
        for m in ("dense", "exact-topk", "loki", "quest", "hata",
                  "lsh"):
            by = baselines.decode_bytes_per_kv_head(
                m, s, d, budget=budget, rbit=rbit)
            row[m] = by
            row[m + "_us@v5e"] = by / HBM_BW * 1e6
        row["speedup_vs_dense"] = row["dense"] / row["hata"]
        rows.append(row)
    return rows


def wallclock_layer(s=4096, b=4, h=8, h_kv=2, d=64, rbit=64,
                    budget=128):
    """One layer's decode on CPU: dense vs HATA (xla ops path)."""
    rng = np.random.default_rng(0)
    hcfg = HataConfig(rbit=rbit, budget_min=budget, budget_max=budget,
                      budget_frac=budget / s)
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=rbit,
                                  dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2**32, cache.codes.shape,
                                       dtype=np.uint32)))
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)),
                    jnp.float32)
    pos = jnp.int32(s - 2)

    dense = jax.jit(lambda qq: ops.decode_attention(
        qq, cache.k, cache.v, jnp.int32(s - 1)))
    hata = jax.jit(lambda qq: hata_decode(
        qq, k1, v1, w, cache, hcfg=hcfg, pos=pos).out)
    t_dense = timer(dense, q)
    t_hata = timer(hata, q)
    return {"dense_us": t_dense, "hata_us": t_hata,
            "speedup": t_dense / t_hata}


def _legacy_vmapped_decode(q, k1, v1, w, cache, hcfg, pos):
    """The seed's decode data path: per-(B, H_kv) vmapped Hamming kernel,
    per-head vmapped fused gather with clamped indices, plus the exact
    XLA recomputation that the old correction branch always paid."""
    import jax.numpy as jnp
    from repro.core import hash_attention as ha
    rbit = w.shape[-1]
    s_max = cache.max_len
    cache2 = kvcache.append_kv(cache, k1, v1,
                               ops.hash_encode_heads(k1, w), pos)
    q_codes = ha.aggregate_q_codes(q, w, cache.k.shape[2])
    scores = ops.hamming_scores_vmapped(q_codes, cache2.codes, rbit=rbit)
    scores = ha.mask_scores(scores, pos + 1)
    budget = ha.clamped_budget(hcfg, s_max)
    top_scores, idx = jax.lax.top_k(scores, budget)
    sel_valid = top_scores >= 0
    idx_c = jnp.where(sel_valid, idx, 0)
    out = ops.gather_decode_attention_vmapped(q, cache2.k, cache2.v,
                                              idx_c)
    out_exact = ops.gather_decode_attention(q, cache2.k, cache2.v, idx,
                                            sel_valid=sel_valid,
                                            fused=False)
    return jnp.where(jnp.any(~sel_valid), out_exact, out)


def wallclock_batched_pipeline(s=4096, b=4, h=8, h_kv=2, d=64, rbit=64,
                               budget=64):
    """Batched fused pipeline vs the seed's vmapped path, pallas
    interpret mode (acceptance shape: B=4, S=4096)."""
    rng = np.random.default_rng(0)
    hcfg = HataConfig(rbit=rbit, budget_min=budget, budget_max=budget,
                      budget_frac=budget / s)
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=rbit,
                                  dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2**32, cache.codes.shape,
                                       dtype=np.uint32)))
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)), jnp.float32)
    # ragged depths: slots at different fill levels, as the engine sees
    pos = jnp.asarray(rng.integers(s // 2, s - 1, b), jnp.int32)

    with ops.use_impl("pallas"):
        batched = jax.jit(lambda qq: hata_decode_batched(
            qq, k1, v1, w, cache, hcfg=hcfg, pos=pos,
            fused_gather=True).out)
        legacy = jax.jit(lambda qq: _legacy_vmapped_decode(
            qq, k1, v1, w, cache, hcfg, pos))
        t_batched = timer(batched, q)
        t_legacy = timer(legacy, q)
    return {"batched_us": t_batched, "vmapped_us": t_legacy,
            "speedup": t_legacy / t_batched}


def main():
    for row in byte_model():
        print(f"decode_bytes/seq{row['seq']}/dense,0,{row['dense']:.0f}")
        print(f"decode_bytes/seq{row['seq']}/hata,0,{row['hata']:.0f}")
        print(f"decode_bytes/seq{row['seq']}/speedup,0,"
              f"{row['speedup_vs_dense']:.2f}")
    wc = wallclock_layer()
    print(f"decode_wallclock/dense,{wc['dense_us']:.0f},1.0")
    print(f"decode_wallclock/hata,{wc['hata_us']:.0f},"
          f"{wc['speedup']:.2f}")
    bp = wallclock_batched_pipeline()
    print(f"decode_pipeline/vmapped,{bp['vmapped_us']:.0f},1.0")
    print(f"decode_pipeline/batched,{bp['batched_us']:.0f},"
          f"{bp['speedup']:.2f}")
    return wc


if __name__ == "__main__":
    main()
