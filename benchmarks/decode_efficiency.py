"""Paper Fig. 4/5 analogue: decode-step cost across methods, sequence
lengths and batch sizes.

Two views:
  * HBM byte model (first principles, v5e constants): on the
    memory-bound decode roofline, speedup == byte ratio — this is the
    at-scale prediction.
  * CPU wall-clock of one attention layer's decode (xla path): sanity
    check that the implemented ops realize the predicted ordering.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.configs.base import HataConfig
from repro.core import baselines, kvcache
from repro.core.hash_attention import hata_decode
from repro.kernels import ops
from repro.launch.analytic import HBM_BW


def byte_model(seqs=(32768, 131072, 262144), budget_frac=0.0156,
               d=128, rbit=128):
    rows = []
    for s in seqs:
        budget = max(512, int(budget_frac * s))
        row = {"seq": s}
        for m in ("dense", "exact-topk", "loki", "quest", "hata",
                  "lsh"):
            by = baselines.decode_bytes_per_kv_head(
                m, s, d, budget=budget, rbit=rbit)
            row[m] = by
            row[m + "_us@v5e"] = by / HBM_BW * 1e6
        row["speedup_vs_dense"] = row["dense"] / row["hata"]
        rows.append(row)
    return rows


def wallclock_layer(s=4096, b=4, h=8, h_kv=2, d=64, rbit=64,
                    budget=128):
    """One layer's decode on CPU: dense vs HATA (xla ops path)."""
    rng = np.random.default_rng(0)
    hcfg = HataConfig(rbit=rbit, budget_min=budget, budget_max=budget,
                      budget_frac=budget / s)
    cache = kvcache.init_kv_cache(b, s, h_kv, d, rbit=rbit,
                                  dtype=jnp.float32)
    cache = dataclasses.replace(
        cache,
        k=jnp.asarray(rng.standard_normal(cache.k.shape), jnp.float32),
        v=jnp.asarray(rng.standard_normal(cache.v.shape), jnp.float32),
        codes=jnp.asarray(rng.integers(0, 2**32, cache.codes.shape,
                                       dtype=np.uint32)))
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((b, 1, h_kv, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((h_kv, d, rbit)),
                    jnp.float32)
    pos = jnp.int32(s - 2)

    dense = jax.jit(lambda qq: ops.decode_attention(
        qq, cache.k, cache.v, jnp.int32(s - 1)))
    hata = jax.jit(lambda qq: hata_decode(
        qq, k1, v1, w, cache, hcfg=hcfg, pos=pos).out)
    t_dense = timer(dense, q)
    t_hata = timer(hata, q)
    return {"dense_us": t_dense, "hata_us": t_hata,
            "speedup": t_dense / t_hata}


def main():
    for row in byte_model():
        print(f"decode_bytes/seq{row['seq']}/dense,0,{row['dense']:.0f}")
        print(f"decode_bytes/seq{row['seq']}/hata,0,{row['hata']:.0f}")
        print(f"decode_bytes/seq{row['seq']}/speedup,0,"
              f"{row['speedup_vs_dense']:.2f}")
    wc = wallclock_layer()
    print(f"decode_wallclock/dense,{wc['dense_us']:.0f},1.0")
    print(f"decode_wallclock/hata,{wc['hata_us']:.0f},"
          f"{wc['speedup']:.2f}")
    return wc


if __name__ == "__main__":
    main()
